//! The sharded EdgeRAG index: clusters partitioned across `N`
//! independently locked shards so one query fans its probed clusters out
//! to a scoped worker pool and structural updates stall only the owning
//! shard.
//!
//! ## Why shard
//!
//! EdgeRAG's retrieval splits into a centroid probe plus per-cluster
//! work (load / cache peek / online generation, then an in-cluster
//! scan). The per-cluster stage is embarrassingly parallel, but a
//! single [`EdgeIndex`] walks all probed clusters on one thread and all
//! queries share one cache lock, one threshold lock and one write lease
//! for updates. [`ShardedEdgeIndex`] partitions clusters round-robin
//! across `N` shards — each shard is a complete [`EdgeIndex`] over its
//! subset, with its **own** cost-aware cache, adaptive-threshold
//! controller and update generation behind its **own** `RwLock` — so:
//!
//! * a query's probed clusters execute as per-shard cluster walks, in
//!   parallel on the shard pool, and the per-shard top-k heaps merge
//!   back in probe order;
//! * the centroid probe scores against a **lock-free [`ProbeTable`]
//!   snapshot** (invalidated by structural updates, rebuilt lazily by
//!   the next probe), so a newly arriving query takes no shard lease at
//!   all during its probe and never waits behind an in-flight insert;
//! * an online insert/remove takes only the owning shard's write lease:
//!   cluster walks and intent commits touching other shards proceed
//!   concurrently;
//! * each shard's deferred [`CacheIntent`] commits independently under
//!   that shard's locks.
//!
//! ## Equivalence with the unsharded index
//!
//! Sharding must not change retrieval results. Three mechanisms make the
//! sharded walk reproduce the sequential one exactly:
//!
//! 1. probes are selected from a **global** score table (the
//!    [`ProbeTable`] snapshot holds every shard's centroids spliced into
//!    global cluster order), so the probed set and order — ties
//!    included — match the unsharded probe;
//! 2. every shard runs the *same* cluster-walk code
//!    ([`EdgeIndex::search_clusters`]) over its subsequence of the probe
//!    order, tagging each cluster's candidates with their global probe
//!    position;
//! 3. the merge re-sorts candidate groups by probe position before the
//!    final top-k, recreating the exact candidate order (and therefore
//!    the exact ties) a sequential walk produces.
//!
//! With `shards = 1` the whole path degenerates to a single
//! [`EdgeIndex`] walk and is bit-identical to it. With `shards > 1` the
//! top-k ids/scores are still identical; only cache *capacity placement*
//! changes (the byte budget splits evenly across shards, and each shard
//! adapts its own threshold from the queries that touch it).
//!
//! ## Cluster ids and ownership
//!
//! Shards use dense local cluster ids internally. Global cluster ids are
//! allocated densely in creation order (the initial partition assigns
//! `0..n` round-robin; every split appends the next free global id —
//! exactly the id sequence an unsharded index allocates for the same op
//! stream). The global→(shard, local) mapping lives in an explicit
//! `Ownership` table rather than a formula, because the **online
//! rebalancer** ([`crate::index::rebalance`]) migrates clusters between
//! shards: a migrated cluster keeps its global id (so probe order, probe
//! output and search results are untouched) while its (shard, local)
//! position changes. [`SearchOutcome::probed`] and the cluster ids
//! returned by [`ShardedEdgeIndex::insert_chunk`] are global ids.
//!
//! ## Locking
//!
//! Lock order is strictly `updates mutex → ownership RwLock → probe-heat
//! / co-probe tables → topology RwLock → shard RwLock → controller →
//! cache → memory model`, and no thread ever holds two shard locks at
//! once (probing reads only the snapshot; routing and snapshot rebuilds
//! visit shards sequentially, one read lock at a time; fan-out workers
//! each take exactly one). Structural mutations (insert, remove,
//! migrate, merge) serialize on the updates mutex — they are rare and
//! heavy, and serializing them keeps the composed structural sequences
//! (migration's copy→flip→retire, a cross-shard merge's
//! migrate-then-merge) atomic against other structural ops; searches
//! never touch the mutex. A search holds the ownership **read** lock from
//! probe-list grouping through its cluster walks, so a migration's
//! ownership flip (the write lock) naturally drains every search still
//! routed at the pre-flip owner before the source copy is retired.
//!
//! The shard set itself lives behind the **topology** lock as an
//! `Arc<Topology>` snapshot ([`ShardedEdgeIndex::grow_shards`] /
//! [`ShardedEdgeIndex::shrink_shards`] swap it online). The lock is held
//! only to clone or swap the `Arc`; a search clones the snapshot *while
//! holding the ownership read lock*, and every swap happens under the
//! ownership **write** lock (plus the updates mutex), so the shard
//! indices a search resolves through `Ownership` always index the
//! topology snapshot it walks — a reshard can never tear a search. See
//! `docs/ARCHITECTURE.md` for the full hierarchy including the engine
//! lease above this one.

use std::collections::HashMap;
use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};

use anyhow::Result;

use crate::cache::CacheStats;
use crate::config::{DeviceProfile, IndexKind, RetrievalConfig};
use crate::index::edge::{ClusterHits, ClusterWalk};
use crate::index::{
    CacheIntent, ClusterMeta, ClusterSet, EdgeIndex, EmbedSource, ProbeTable, Scorer,
    SearchEvents, SearchOutcome, ShardWalk, SharedMemory, VectorIndex,
};
use crate::pool::{Job, SubmitError, WorkerPool};
use crate::simtime::{Component, LatencyLedger, SimDuration};
use crate::storage::{BlobStore, WalActivity, WalOp, WriteAheadLog};
use crate::trace;
use crate::vecmath::{self, EmbeddingMatrix};

/// Hard ceiling on the shard count: shard `i` namespaces its memory-model
/// regions at `i << 24`, leaving 24 bits of local cluster ids per shard.
pub const MAX_SHARDS: usize = 256;

/// Rows of per-cluster probe heat surfaced per shard in
/// [`ShardStats::hot_clusters`] (the full table is available through
/// [`ShardedEdgeIndex::cluster_probe_heat`]).
pub const HOT_CLUSTERS: usize = 16;

/// `Ownership::locals` marker for a local slot whose cluster migrated
/// away: the slot stays (local ids are never reused) but maps to no
/// global cluster.
pub(crate) const ORPHAN: u32 = u32::MAX;

/// Cap on distinct co-probe affinity pairs tracked. At the cap, existing
/// pairs keep counting but no new pair is admitted until decay prunes
/// cold ones — the table is a placement heuristic, not an invariant, so
/// bounded staleness beats unbounded memory.
pub(crate) const MAX_AFFINITY_PAIRS: usize = 4096;

// ---------------------------------------------------------------------------
// Ownership: global cluster id ⇄ (shard, local)
// ---------------------------------------------------------------------------

/// The dynamic global→(shard, local) cluster mapping. Before the online
/// rebalancer existed this was the formula `g ↦ (g % k, g / k)`; with
/// migration it is explicit state: a migrated cluster keeps its global id
/// while its (shard, local) position changes.
///
/// Invariants (checked by
/// [`ShardedEdgeIndex::verify_integrity`](crate::index::ShardedEdgeIndex::verify_integrity)):
/// every global id maps to exactly one live (shard, local) slot;
/// `locals[s][l] == g ⇔ owner[g] == (s, l)`; retired migration sources
/// are [`ORPHAN`] slots whose shard-side cluster is tombstoned and
/// resource-free.
#[derive(Debug)]
pub(crate) struct Ownership {
    /// Indexed by global cluster id → (shard, local).
    pub(crate) owner: Vec<(u32, u32)>,
    /// `[shard][local]` → global id, or [`ORPHAN`].
    pub(crate) locals: Vec<Vec<u32>>,
}

impl Ownership {
    /// Current owner of a global cluster id.
    pub(crate) fn owner_of(&self, global: u32) -> Option<(usize, u32)> {
        self.owner
            .get(global as usize)
            .map(|&(s, l)| (s as usize, l))
    }

    /// Global id of shard `shard`'s local cluster `local` (None for
    /// orphaned slots and not-yet-registered locals).
    pub(crate) fn global_of(&self, shard: usize, local: u32) -> Option<u32> {
        self.locals[shard]
            .get(local as usize)
            .copied()
            .filter(|&g| g != ORPHAN)
    }
}

// ---------------------------------------------------------------------------
// Per-shard serving counters
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
pub(crate) struct ShardCounters {
    probes: AtomicU64,
    cache_hits: AtomicU64,
    generated: AtomicU64,
    loaded: AtomicU64,
    inserts: AtomicU64,
    removes: AtomicU64,
    pub(crate) migrated_in: AtomicU64,
    pub(crate) migrated_out: AtomicU64,
    /// Drained clusters this shard absorbed as a merge victim (local or
    /// cross-shard).
    merges: AtomicU64,
}

/// One shard's serving statistics snapshot (the `stats` / `shard-stats`
/// endpoints' per-shard rows). The rebalance planner and the churn test
/// suite assert against these same numbers — see
/// [`ShardedEdgeIndex::cluster_loads`](crate::index::ShardedEdgeIndex::cluster_loads).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Shard index.
    pub shard: usize,
    /// Active (non-tombstone) clusters currently owned by this shard.
    pub clusters: usize,
    /// Total chunk rows across this shard's active clusters — the
    /// primary rebalance load measure.
    pub rows: u64,
    /// Probed clusters routed to this shard so far.
    pub probes: u64,
    /// Embedding-cache hits served by this shard.
    pub cache_hits: u64,
    /// Clusters this shard generated online.
    pub generated: u64,
    /// Clusters this shard loaded from its blob store.
    pub loaded: u64,
    /// Online insertions routed to this shard.
    pub inserts: u64,
    /// Online removals routed to this shard.
    pub removes: u64,
    /// Clusters migrated **into** this shard by the rebalancer.
    pub migrated_in: u64,
    /// Clusters migrated **out of** this shard by the rebalancer.
    pub migrated_out: u64,
    /// Drained clusters this shard absorbed as a merge victim (the
    /// cross-shard merge router counts the absorbing side).
    pub merges: u64,
    /// Hottest clusters currently owned by this shard: `(global id,
    /// probes)` in descending probe-heat order, capped at
    /// [`HOT_CLUSTERS`] rows — the per-cluster half of the probe
    /// counters (the per-shard totals ride in `probes`), and the input a
    /// future affinity-aware placement policy would score on.
    pub hot_clusters: Vec<(u32, u64)>,
    /// This shard's current adaptive caching threshold (ms).
    pub threshold_ms: f64,
    /// Bytes resident in this shard's embedding cache.
    pub cache_used_bytes: u64,
    /// This shard's full cache statistics (hits/misses/insertions/…);
    /// previously only the cross-shard aggregate was exposed.
    pub cache: CacheStats,
}

// ---------------------------------------------------------------------------
// The live shard set (elastic)
// ---------------------------------------------------------------------------

/// An immutable snapshot of the live shard set: the shards themselves
/// plus their serving counters, swapped as one `Arc` by
/// [`ShardedEdgeIndex::grow_shards`] / [`ShardedEdgeIndex::shrink_shards`].
/// Each shard (and counter block) is its own `Arc` so a swap clones only
/// the spine: surviving shards keep their identity — and their in-flight
/// read leases — across a reshard, and fan-out jobs on the pool can
/// borrow a shard without tying its lifetime to the calling query.
pub(crate) struct Topology {
    pub(crate) shards: Vec<Arc<RwLock<EdgeIndex>>>,
    pub(crate) counters: Vec<Arc<ShardCounters>>,
}

impl Topology {
    pub(crate) fn len(&self) -> usize {
        self.shards.len()
    }
}

// ---------------------------------------------------------------------------
// The sharded index
// ---------------------------------------------------------------------------

/// Clusters partitioned across `N` independently locked [`EdgeIndex`]
/// shards (see the module docs for the design and equivalence argument).
/// `N` is elastic: [`ShardedEdgeIndex::reshard`] grows or shrinks the
/// live shard set online.
pub struct ShardedEdgeIndex {
    kind: IndexKind,
    /// The live shard set, behind the topology lock (held only to clone
    /// or swap the `Arc`; see the module docs for where it sits in the
    /// hierarchy). Every swap runs under the ownership write lock, so a
    /// snapshot cloned under the ownership read lock is always exactly
    /// the set the ownership table indexes.
    topology: RwLock<Arc<Topology>>,
    nprobe: usize,
    device: DeviceProfile,
    pub(crate) scorer: Scorer,
    /// The dynamic global⇄(shard, local) cluster mapping. Searches hold
    /// the read lock from probe grouping through their cluster walks; a
    /// migration's ownership flip takes the write lock, which therefore
    /// drains every search still routed at the pre-flip owner before the
    /// source copy is retired.
    pub(crate) ownership: RwLock<Ownership>,
    /// Serializes structural mutations (insert / remove / migrate)
    /// against each other — never taken by searches. Holding it across a
    /// whole migration makes copy→flip→retire atomic with respect to
    /// inserts that could otherwise route into the doomed source copy.
    pub(crate) updates_serial: Mutex<()>,
    /// Structural updates completed since build (the periodic-rebalance
    /// trigger counts these against `rebalance_interval_ops`).
    update_ops: AtomicU64,
    /// Run a rebalance round after every this many updates (0 = off).
    rebalance_every: usize,
    /// Migration budget per rebalance round.
    pub(crate) max_migrations: usize,
    /// Serializes whole rebalance rounds (plan + execute) so an explicit
    /// `rebalance` op and the periodic trigger cannot interleave moves
    /// planned from different load snapshots — which could thrash or
    /// even increase the spread. Sits above `updates_serial`: a round
    /// holds it while each migration takes the updates mutex; nothing
    /// acquires it while holding any other lock.
    pub(crate) rebalance_serial: Mutex<()>,
    /// Persistent pool executing per-(query, shard) cluster walks. Any
    /// worker may serve any shard (walks take only shard read leases).
    pool: WorkerPool,
    /// The spliced first-level snapshot queries probe against **without
    /// any shard lease** — a probing query never queues behind an
    /// in-flight structural update. Updates that touch the first level
    /// (splits, merges — plain inserts/removes change neither centroids
    /// nor liveness) only mark it stale (`table_stale`); the next probe
    /// rebuilds it lazily, so an update burst pays one rebuild, not one
    /// per update. The lock is held only to clone or swap the `Arc`.
    probe_table: RwLock<Arc<ProbeTable>>,
    /// Set by structural updates after their shard write completes;
    /// cleared by the (serialized) lazy rebuild.
    table_stale: AtomicBool,
    /// Serializes snapshot rebuilds so concurrent probes after an update
    /// trigger exactly one rebuild and later rebuilds see every
    /// completed update.
    table_rebuild: Mutex<()>,
    /// Per-cluster probe-heat counters, indexed by global cluster id
    /// (ROADMAP gap: probe counters used to be per-shard only). Grown
    /// lazily as new globals are probed; read-mostly — searches bump
    /// counters under the read lock. Sits between the ownership lock
    /// and the shard leases in the hierarchy: searches take it (briefly,
    /// under ownership read) before their walks, `shard_stats` holds it
    /// across shard read leases, and nothing holding a shard lease ever
    /// acquires it.
    probe_heat: RwLock<Vec<AtomicU64>>,
    /// Co-probe affinity: for each unordered global-id pair `(a, b)`
    /// (keyed `a < b`), how many searches probed both in one probe list.
    /// The heat-aware planner reads it to co-locate co-probed clusters
    /// (see [`crate::index::rebalance::plan_rebalance`]); bounded at
    /// [`MAX_AFFINITY_PAIRS`] and halved alongside the heat decay. Sits
    /// at the same level as `probe_heat` in the lock hierarchy.
    co_probe: Mutex<HashMap<(u32, u32), u64>>,
    /// Halve every heat counter and affinity edge after every this many
    /// structural updates (0 = never): without decay the counters are
    /// monotone lifetime totals and placement chases historical hot
    /// spots forever.
    heat_decay_every: usize,
    // -- Retained build materials so `grow_shards` can construct fresh
    //    empty shards identical to what `build` would have made. --
    source: EmbedSource,
    blob_dir: Option<PathBuf>,
    memory: SharedMemory,
    retrieval_cfg: RetrievalConfig,
    store_limit: SimDuration,
    slo: SimDuration,
    /// Structural write-ahead log, owned at the *wrapper* level: the
    /// per-shard [`EdgeIndex`]es keep `wal: None`, so their internal
    /// appends no-op and every record here carries **global** ids.
    /// Appends run under `updates_serial`, before the shard write lease
    /// (level 2 of the lock hierarchy); the WAL takes no index locks.
    wal: Option<Arc<WriteAheadLog>>,
    /// True while [`ShardedEdgeIndex::replay_wal`] drives recovered ops
    /// through the normal update path: suppresses the periodic-rebalance
    /// trigger, whose decisions depend on cache state that is defined
    /// cold after recovery — replay must be a pure function of the op
    /// sequence.
    replaying: AtomicBool,
    /// Lazy probe-snapshot rebuilds performed (observability counter;
    /// bumped under `table_rebuild`, read lock-free).
    probe_rebuilds: AtomicU64,
}

impl ShardedEdgeIndex {
    /// Partition `clusters` round-robin across `shards` shards and build
    /// one [`EdgeIndex`] per shard. The cache byte budget in `retrieval`
    /// splits evenly; `blob_dir` (required when `kind` uses selective
    /// storage) gets one `shard{i}` subdirectory per shard.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        kind: IndexKind,
        clusters: ClusterSet,
        source: EmbedSource,
        blob_dir: Option<&Path>,
        scorer: Scorer,
        memory: SharedMemory,
        device: DeviceProfile,
        retrieval: &RetrievalConfig,
        store_limit: SimDuration,
        slo: SimDuration,
        shards: usize,
    ) -> Result<Self> {
        let k = shards.max(1);
        anyhow::ensure!(k <= MAX_SHARDS, "at most {MAX_SHARDS} shards");
        anyhow::ensure!(
            clusters.n_clusters() < (1 << 24),
            "cluster ids must fit the 24-bit per-shard namespace"
        );
        let dim = clusters.centroids.dim;

        // Round-robin partition: global cluster `g` → shard `g % k`,
        // local id `g / k`. Round-robin (rather than contiguous ranges)
        // balances the tail-heavy cluster-size distribution in
        // expectation.
        let mut parts: Vec<(EmbeddingMatrix, Vec<ClusterMeta>)> = (0..k)
            .map(|_| (EmbeddingMatrix::new(dim), Vec::new()))
            .collect();
        for (g, meta) in clusters.clusters.iter().enumerate() {
            let (centroids, metas) = &mut parts[g % k];
            centroids.push(clusters.centroids.row(g));
            metas.push(ClusterMeta {
                id: metas.len() as u32,
                chunk_ids: meta.chunk_ids.clone(),
                chars: meta.chars,
                gen_cost: meta.gen_cost,
            });
        }

        // Each shard gets an even slice of the cache byte budget.
        let mut per_shard = retrieval.clone();
        per_shard.cache_capacity_bytes = (retrieval.cache_capacity_bytes / k as u64).max(1);

        let mut built = Vec::with_capacity(k);
        for (i, (centroids, metas)) in parts.into_iter().enumerate() {
            let set = ClusterSet {
                centroids,
                clusters: metas,
            };
            let blob = if kind.uses_storage() {
                let dir = blob_dir
                    .ok_or_else(|| anyhow::anyhow!("selective storage requires a blob dir"))?;
                Some(BlobStore::open(&dir.join(format!("shard{i}")), dim)?)
            } else {
                None
            };
            let mut shard = EdgeIndex::build(
                kind,
                set,
                source.clone(),
                blob,
                scorer.clone(),
                memory.clone(),
                device.clone(),
                &per_shard,
                store_limit,
                slo,
            )?;
            shard.set_region_base((i as u32) << 24);
            built.push(Arc::new(RwLock::new(shard)));
        }

        // Initial ownership mirrors the round-robin partition: global
        // cluster `g` lives at shard `g % k`, local `g / k`. From here on
        // the table, not the formula, is authoritative (splits append new
        // globals; migrations move them).
        let n = clusters.n_clusters();
        let owner: Vec<(u32, u32)> = (0..n)
            .map(|g| ((g % k) as u32, (g / k) as u32))
            .collect();
        let mut locals: Vec<Vec<u32>> = vec![Vec::new(); k];
        for (g, &(s, l)) in owner.iter().enumerate() {
            debug_assert_eq!(locals[s as usize].len(), l as usize);
            locals[s as usize].push(g as u32);
        }

        // Pool sizing: the calling thread always walks one shard-group
        // itself, so at most `k − 1` walks per query run remotely; more
        // workers than cores just adds scheduler churn. A configured
        // elastic ceiling (`shards_max`) sizes the pool for the largest
        // topology a later `grow_shards` may install, so growth never
        // needs to resize the pool.
        let pool_ceiling = match retrieval.shards_max {
            0 => k,
            m => k.max(m.min(MAX_SHARDS)),
        };
        let workers = pool_ceiling
            .saturating_sub(1)
            .min(crate::config::default_shards());
        let index = ShardedEdgeIndex {
            kind,
            topology: RwLock::new(Arc::new(Topology {
                shards: built,
                counters: (0..k).map(|_| Arc::new(ShardCounters::default())).collect(),
            })),
            nprobe: retrieval.nprobe,
            device,
            scorer,
            ownership: RwLock::new(Ownership { owner, locals }),
            updates_serial: Mutex::new(()),
            update_ops: AtomicU64::new(0),
            rebalance_every: if retrieval.rebalance {
                retrieval.rebalance_interval_ops
            } else {
                0
            },
            max_migrations: retrieval.max_migrations_per_round,
            rebalance_serial: Mutex::new(()),
            pool: WorkerPool::new("edgerag-shard", workers),
            probe_table: RwLock::new(Arc::new(ProbeTable {
                centroids: EmbeddingMatrix::new(dim),
                ids: Vec::new(),
                active: Vec::new(),
                centroid_bytes: 0,
                generation: 0,
            })),
            table_stale: AtomicBool::new(false),
            table_rebuild: Mutex::new(()),
            probe_heat: RwLock::new((0..n).map(|_| AtomicU64::new(0)).collect()),
            co_probe: Mutex::new(HashMap::new()),
            heat_decay_every: retrieval.heat_decay_interval_ops,
            source,
            blob_dir: blob_dir.map(Path::to_path_buf),
            memory,
            retrieval_cfg: retrieval.clone(),
            store_limit,
            slo,
            wal: None,
            replaying: AtomicBool::new(false),
            probe_rebuilds: AtomicU64::new(0),
        };
        {
            let _serial = index.table_rebuild.lock().unwrap();
            let _built_table = index.rebuild_probe_table();
            debug_assert!(_built_table, "initial rebuild cannot be torn");
        }
        Ok(index)
    }

    /// Snapshot the live shard set (one lock acquire + `Arc` clone).
    /// Callers that index `Ownership::locals` against the snapshot must
    /// take it while holding the ownership lock (any mode): swaps run
    /// under the ownership write lock, so the two can never disagree.
    /// Callers under `updates_serial` or `rebalance_serial` see a stable
    /// topology for the whole critical section (swaps take both).
    pub(crate) fn topo(&self) -> Arc<Topology> {
        self.topology.read().unwrap().clone()
    }

    /// The current probe snapshot, rebuilding lazily if a structural
    /// update invalidated it. The common (fresh) path is one atomic load
    /// plus an `Arc` clone.
    fn probe_table_current(&self) -> Arc<ProbeTable> {
        if self.table_stale.load(Ordering::Acquire) {
            let _serial = self.table_rebuild.lock().unwrap();
            // Claim-then-build: clear the flag *before* reading shard
            // state, so an update landing mid-rebuild re-marks it and
            // the next probe rebuilds again — a completed update can
            // never be silently missed. A rebuild that observed a torn
            // mid-registration split re-marks the flag itself and the
            // old (still oracle-consistent) snapshot keeps serving.
            if self.table_stale.swap(false, Ordering::AcqRel) {
                if self.rebuild_probe_table() {
                    self.probe_rebuilds.fetch_add(1, Ordering::Relaxed);
                    trace::record_event("probe_rebuild", &[]);
                } else {
                    self.table_stale.store(true, Ordering::Release);
                }
            }
        }
        self.probe_table.read().unwrap().clone()
    }

    /// Rebuild the spliced probe snapshot from the current shard state.
    /// Caller must hold `table_rebuild`; takes the ownership read lock,
    /// then one shard read lease at a time — never two at once, per the
    /// lock hierarchy.
    ///
    /// Returns false — leaving the previous snapshot installed — when a
    /// shard's state is ahead of the ownership table (an in-flight
    /// insert's split has mutated the shard but not yet registered its
    /// new cluster; registration is blocked behind this rebuild's
    /// ownership read lock). Splicing that state would mix a post-split
    /// centroid with a pre-split cluster list — a table matching no
    /// oracle instant. The caller re-marks the snapshot stale and the
    /// next probe retries once registration completes.
    fn rebuild_probe_table(&self) -> bool {
        let own = self.ownership.read().unwrap();
        let topo = self.topo();
        // Per-shard copies first (one lease at a time), splice after.
        let mut parts: Vec<(EmbeddingMatrix, Vec<bool>)> = Vec::with_capacity(topo.len());
        let mut generation = 0u64;
        for (s, shard) in topo.shards.iter().enumerate() {
            let guard = shard.read().unwrap();
            if guard.clusters().n_clusters() != own.locals[s].len() {
                return false; // torn: shard mutated ahead of registration
            }
            generation += guard.update_generation();
            parts.push((
                guard.clusters().centroids.clone(),
                guard.active_flags().to_vec(),
            ));
        }
        // Splice into ascending global-id order — the exact traversal
        // order an unsharded index scores its clusters in, so `top_k`'s
        // lower-index tie preference is preserved. One row per global id
        // ever allocated (tombstones included), which is also why the
        // modeled `centroid_bytes` charge below matches the unsharded
        // index byte for byte even after migrations leave orphaned
        // centroid copies behind on their source shards.
        let dim = parts.first().map_or(0, |(c, _)| c.dim);
        let mut centroids = EmbeddingMatrix::with_capacity(dim, own.owner.len());
        let mut ids = Vec::with_capacity(own.owner.len());
        let mut active = Vec::with_capacity(own.owner.len());
        for (g, &(s, l)) in own.owner.iter().enumerate() {
            let (cent, act) = &parts[s as usize];
            centroids.push(cent.row(l as usize));
            ids.push(g as u32);
            active.push(act[l as usize]);
        }
        let centroid_bytes = centroids.bytes();
        *self.probe_table.write().unwrap() = Arc::new(ProbeTable {
            centroids,
            ids,
            active,
            centroid_bytes,
            generation,
        });
        true
    }

    /// Number of shards (the *current* count — [`ShardedEdgeIndex::reshard`]
    /// changes it online).
    pub fn shards(&self) -> usize {
        self.topo().len()
    }

    /// Owning shard of a global cluster id (its *current* owner — the
    /// rebalancer may move it).
    pub fn shard_of(&self, global_cluster: u32) -> usize {
        self.ownership
            .read()
            .unwrap()
            .owner_of(global_cluster)
            .map(|(s, _)| s)
            .unwrap_or_else(|| panic!("unknown global cluster {global_cluster}"))
    }

    /// Run `f` against one shard under its read lease (introspection and
    /// tests; holding the guard blocks only that shard's writers).
    pub fn with_shard<R>(&self, shard: usize, f: impl FnOnce(&EdgeIndex) -> R) -> R {
        let topo = self.topo();
        f(&topo.shards[shard].read().unwrap())
    }

    /// Override the probe width (harness sweeps).
    pub fn set_nprobe(&mut self, nprobe: usize) {
        self.nprobe = nprobe;
    }

    /// Attach a structural write-ahead log at the wrapper level (the
    /// per-shard indexes stay WAL-less, so records carry global ids).
    /// Call after [`ShardedEdgeIndex::replay_wal`], never before —
    /// replayed ops must not be re-logged.
    pub fn attach_wal(&mut self, wal: Arc<WriteAheadLog>) {
        self.wal = Some(wal);
    }

    /// The attached WAL, if any (fault-injection suites arm its crash
    /// points through this).
    pub fn wal(&self) -> Option<&Arc<WriteAheadLog>> {
        self.wal.as_ref()
    }

    /// Append `op` before the mutation it describes; a no-op without an
    /// attached WAL. Caller holds `updates_serial` and no shard lease.
    pub(crate) fn wal_append(&self, op: &WalOp) -> Result<()> {
        match &self.wal {
            Some(w) => w.append(op),
            None => Ok(()),
        }
    }

    /// Rebuild structural state from a recovered WAL op sequence by
    /// driving the ordinary update path: inserts route, split, and
    /// allocate global ids exactly as they did live; removes re-derive
    /// their merges; migrations re-execute (skipped when the recorded
    /// destination exceeds this deployment's shard count — a log is
    /// portable down-shard, and placement re-converges via rebalance).
    /// `Split`/`Merge` are derived audit records and are skipped. The
    /// periodic-rebalance trigger is suppressed throughout: replay must
    /// be a pure function of the op sequence, while the trigger's
    /// decisions depend on cache state that is defined cold after
    /// recovery. Call on a freshly built index with no WAL attached;
    /// attach the log afterwards.
    pub fn replay_wal(&self, ops: &[WalOp]) -> Result<()> {
        self.replaying.store(true, Ordering::Release);
        let result = (|| -> Result<()> {
            for op in ops {
                match op {
                    WalOp::Insert { id, text, emb } => {
                        self.insert_chunk(*id, text, emb)?;
                    }
                    WalOp::Remove { id } => {
                        self.remove_chunk(*id)?;
                    }
                    WalOp::Migrate { global, dest } => {
                        if (*dest as usize) < self.shards() {
                            self.migrate_cluster(*global, *dest as usize)?;
                        }
                    }
                    WalOp::PinThreshold { ms } => self.pin_threshold(*ms),
                    WalOp::Split { .. } | WalOp::Merge { .. } => {}
                }
            }
            Ok(())
        })();
        self.replaying.store(false, Ordering::Release);
        result
    }

    /// Pin every shard's caching threshold and disable adaptation (the
    /// Fig. 7 sweep, applied uniformly). Serialized with the structural
    /// ops so its WAL record lands in a deterministic position.
    pub fn pin_threshold(&self, threshold_ms: f64) {
        let _serial = self.updates_serial.lock().unwrap();
        // Record-before-mutation: an append failure skips the pin rather
        // than mutate unlogged state.
        if self
            .wal_append(&WalOp::PinThreshold { ms: threshold_ms })
            .is_err()
        {
            return;
        }
        for shard in self.topo().shards.iter() {
            shard.write().unwrap().pin_threshold(threshold_ms);
        }
    }

    /// Aggregate cache statistics across shards (None when this
    /// configuration has no cache).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        if !self.kind.uses_cache() {
            return None;
        }
        let mut total = CacheStats::default();
        for shard in self.topo().shards.iter() {
            if let Some(s) = shard.read().unwrap().cache_stats() {
                total.hits += s.hits;
                total.misses += s.misses;
                total.insertions += s.insertions;
                total.evictions += s.evictions;
                total.rejected_below_threshold += s.rejected_below_threshold;
            }
        }
        Some(total)
    }

    /// Total bytes resident across all shard caches.
    pub fn cache_used_bytes(&self) -> u64 {
        self.topo()
            .shards
            .iter()
            .map(|s| s.read().unwrap().cache_used_bytes())
            .sum()
    }

    /// Global ids of every cluster currently resident in any shard's
    /// cache, sorted (equivalence tests, stats). During a live migration
    /// an entry may exist on two shards, but only the owning side maps to
    /// a global id, so each global appears at most once (the dedup is
    /// belt and braces).
    pub fn cached_clusters(&self) -> Vec<u32> {
        let own = self.ownership.read().unwrap();
        let topo = self.topo();
        let mut all = Vec::new();
        for (s, shard) in topo.shards.iter().enumerate() {
            for local in shard.read().unwrap().cached_clusters() {
                if let Some(g) = own.global_of(s, local) {
                    all.push(g);
                }
            }
        }
        all.sort_unstable();
        all.dedup();
        all
    }

    /// Total clusters persisted across all shard blob stores.
    pub fn stored_clusters(&self) -> usize {
        self.topo()
            .shards
            .iter()
            .map(|s| s.read().unwrap().stored_clusters())
            .sum()
    }

    /// Total bytes persisted across all shard blob stores.
    pub fn stored_bytes(&self) -> u64 {
        self.topo()
            .shards
            .iter()
            .map(|s| s.read().unwrap().stored_bytes())
            .sum()
    }

    /// Mean adaptive threshold across shards (each shard adapts its own;
    /// the scalar is for dashboards — see [`ShardedEdgeIndex::shard_stats`]
    /// for the per-shard values).
    pub fn threshold_ms(&self) -> f64 {
        let topo = self.topo();
        let sum: f64 = topo
            .shards
            .iter()
            .map(|s| s.read().unwrap().threshold_ms())
            .sum();
        sum / topo.len() as f64
    }

    /// Active (non-tombstone) clusters across all shards.
    pub fn active_clusters(&self) -> usize {
        self.topo()
            .shards
            .iter()
            .map(|s| s.read().unwrap().active_clusters())
            .sum()
    }

    /// Global cluster currently holding `chunk`, if any. Ownership-aware:
    /// a shard-side hit on a cluster that migrated away (or an import not
    /// yet flipped in) is skipped, so exactly the owning copy answers.
    pub fn cluster_of(&self, chunk: u32) -> Option<u32> {
        let own = self.ownership.read().unwrap();
        let topo = self.topo();
        for (s, shard) in topo.shards.iter().enumerate() {
            if let Some(local) = shard.read().unwrap().cluster_of(chunk) {
                if let Some(g) = own.global_of(s, local) {
                    return Some(g);
                }
            }
        }
        None
    }

    /// Count one search's probed globals into the per-cluster heat
    /// table, growing it when a probe names a global past the current
    /// end (a split registered since the table last grew), and bump the
    /// co-probe affinity edge for every pair in the probe list.
    fn note_probes(&self, probed: &[u32]) {
        let need = probed.iter().map(|&g| g as usize + 1).max().unwrap_or(0);
        let counted = {
            let heat = self.probe_heat.read().unwrap();
            if heat.len() >= need {
                for &g in probed {
                    heat[g as usize].fetch_add(1, Ordering::Relaxed);
                }
                true
            } else {
                false
            }
        };
        if !counted {
            let mut heat = self.probe_heat.write().unwrap();
            while heat.len() < need {
                heat.push(AtomicU64::new(0));
            }
            for &g in probed {
                heat[g as usize].fetch_add(1, Ordering::Relaxed);
            }
        }
        // Pairwise co-probe bumps: O(nprobe²) with nprobe small by
        // design (the paper's sweeps top out well under 32). At the
        // table cap only existing pairs keep counting — decay prunes
        // cold edges and re-opens admission.
        if probed.len() > 1 {
            let mut aff = self.co_probe.lock().unwrap();
            for i in 0..probed.len() {
                for j in (i + 1)..probed.len() {
                    let (a, b) = if probed[i] < probed[j] {
                        (probed[i], probed[j])
                    } else {
                        (probed[j], probed[i])
                    };
                    if a == b {
                        continue;
                    }
                    match aff.get_mut(&(a, b)) {
                        Some(v) => *v += 1,
                        None if aff.len() < MAX_AFFINITY_PAIRS => {
                            aff.insert((a, b), 1);
                        }
                        None => {}
                    }
                }
            }
        }
    }

    /// The full per-cluster probe-heat table: `(global id, probes)` for
    /// every global id with non-zero heat, ascending by id. Heat is
    /// per-global and placement-independent — a migration moves it
    /// implicitly — but it is **not** a lifetime total: a merged-away
    /// cluster's heat is absorbed by its merge victim and its own
    /// counter cleared (so tombstones report no heat), and every counter
    /// halves after each `heat_decay_interval_ops` structural updates so
    /// the table tracks current traffic, not history.
    pub fn cluster_probe_heat(&self) -> Vec<(u32, u64)> {
        self.probe_heat
            .read()
            .unwrap()
            .iter()
            .enumerate()
            .map(|(g, h)| (g as u32, h.load(Ordering::Relaxed)))
            .filter(|&(_, n)| n > 0)
            .collect()
    }

    /// Snapshot of the co-probe affinity table, sorted by pair for
    /// deterministic consumption (the planner and tests).
    pub fn cluster_affinity(&self) -> Vec<((u32, u32), u64)> {
        let mut all: Vec<((u32, u32), u64)> = self
            .co_probe
            .lock()
            .unwrap()
            .iter()
            .map(|(&k, &v)| (k, v))
            .collect();
        all.sort_unstable();
        all
    }

    /// Halve every heat counter and affinity edge, pruning edges that
    /// reach zero. Racing probe bumps may land in the load/store window
    /// and lose one increment — heat is a statistical placement signal,
    /// not an invariant, and the read lock keeps the table itself
    /// stable.
    fn decay_heat(&self) {
        {
            let heat = self.probe_heat.read().unwrap();
            for h in heat.iter() {
                let v = h.load(Ordering::Relaxed);
                if v > 0 {
                    h.store(v / 2, Ordering::Relaxed);
                }
            }
        }
        let mut aff = self.co_probe.lock().unwrap();
        aff.retain(|_, v| {
            *v /= 2;
            *v > 0
        });
    }

    /// Fold a merged-away cluster's heat into its merge victim and clear
    /// the dead counter, then re-key the dead cluster's affinity edges
    /// onto the victim (a pair that collapses into self-affinity is
    /// dropped). Called under `updates_serial` right after a merge
    /// commits; without this the dead global's heat is orphaned forever
    /// and tombstones surface in the heat table.
    fn absorb_heat(&self, dead: u32, victim: u32) {
        if dead == victim {
            return;
        }
        let need = dead.max(victim) as usize + 1;
        let moved = {
            let heat = self.probe_heat.read().unwrap();
            if heat.len() >= need {
                let h = heat[dead as usize].swap(0, Ordering::Relaxed);
                if h > 0 {
                    heat[victim as usize].fetch_add(h, Ordering::Relaxed);
                }
                true
            } else {
                (dead as usize) >= heat.len() // never probed: nothing to move
            }
        };
        if !moved {
            // The victim's row doesn't exist yet: grow under the write
            // lock, then move.
            let mut heat = self.probe_heat.write().unwrap();
            while heat.len() < need {
                heat.push(AtomicU64::new(0));
            }
            let h = heat[dead as usize].swap(0, Ordering::Relaxed);
            if h > 0 {
                heat[victim as usize].fetch_add(h, Ordering::Relaxed);
            }
        }
        let mut aff = self.co_probe.lock().unwrap();
        let touching: Vec<((u32, u32), u64)> = aff
            .iter()
            .filter(|&(&(a, b), _)| a == dead || b == dead)
            .map(|(&k, &v)| (k, v))
            .collect();
        for ((a, b), v) in touching {
            aff.remove(&(a, b));
            let other = if a == dead { b } else { a };
            if other == victim {
                continue;
            }
            let key = (other.min(victim), other.max(victim));
            *aff.entry(key).or_insert(0) += v;
        }
    }

    /// Per-shard serving statistics.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        // Per-shard heat rows need the ownership table; acquisition
        // follows the hierarchy: ownership → heat → shard leases.
        let own = self.ownership.read().unwrap();
        let heat = self.probe_heat.read().unwrap();
        let topo = self.topo();
        topo.shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let guard = shard.read().unwrap();
                let c = &topo.counters[i];
                let mut hot: Vec<(u32, u64)> = own.locals[i]
                    .iter()
                    .enumerate()
                    .filter(|&(l, &g)| g != ORPHAN && guard.active_flags()[l])
                    .filter_map(|(_, &g)| {
                        let n = heat.get(g as usize)?.load(Ordering::Relaxed);
                        (n > 0).then_some((g, n))
                    })
                    .collect();
                hot.sort_by_key(|&(g, n)| (std::cmp::Reverse(n), g));
                hot.truncate(HOT_CLUSTERS);
                ShardStats {
                    shard: i,
                    clusters: guard.active_clusters(),
                    rows: guard.active_rows(),
                    probes: c.probes.load(Ordering::Relaxed),
                    cache_hits: c.cache_hits.load(Ordering::Relaxed),
                    generated: c.generated.load(Ordering::Relaxed),
                    loaded: c.loaded.load(Ordering::Relaxed),
                    inserts: c.inserts.load(Ordering::Relaxed),
                    removes: c.removes.load(Ordering::Relaxed),
                    migrated_in: c.migrated_in.load(Ordering::Relaxed),
                    migrated_out: c.migrated_out.load(Ordering::Relaxed),
                    merges: c.merges.load(Ordering::Relaxed),
                    hot_clusters: hot,
                    threshold_ms: guard.threshold_ms(),
                    cache_used_bytes: guard.cache_used_bytes(),
                    cache: guard.cache_stats().unwrap_or_default(),
                }
            })
            .collect()
    }

    /// The shard an insertion of `emb` would route to: the owner of the
    /// nearest active cluster, selected against the spliced probe
    /// snapshot so tie-breaking (lowest global id) matches an unsharded
    /// index exactly.
    pub fn route(&self, emb: &[f32]) -> Result<usize> {
        let table = self.probe_table_current();
        let scores = table.masked_scores(&self.scorer, emb)?;
        let top = vecmath::top_k(&scores, scores.len(), 1);
        match top.first() {
            Some(&(i, score)) if score.is_finite() => {
                let g = table.ids[i];
                self.ownership
                    .read()
                    .unwrap()
                    .owner_of(g)
                    .map(|(s, _)| s)
                    .ok_or_else(|| anyhow::anyhow!("cluster {g} has no owner"))
            }
            _ => Err(anyhow::anyhow!("no active clusters")),
        }
    }

    /// Register any shard-local clusters created since the last
    /// registration (splits during an insert, migration imports) in the
    /// ownership table, allocating dense global ids in creation order —
    /// the same id sequence an unsharded index allocates. Caller must
    /// hold `updates_serial` and must NOT hold any shard lease (the
    /// ownership write lock waits for in-flight searches).
    fn register_new_locals(&self, shard: usize, up_to: usize) {
        let mut own = self.ownership.write().unwrap();
        while own.locals[shard].len() < up_to {
            let l = own.locals[shard].len() as u32;
            let g = own.owner.len() as u32;
            own.owner.push((shard as u32, l));
            own.locals[shard].push(g);
        }
    }

    /// Insert a chunk (§5.4), write-leasing **only the owning shard**:
    /// queries — to any shard — proceed concurrently; only other
    /// *structural* updates serialize behind this one. `id` must be
    /// globally fresh (the serving engine allocates ids from its shared
    /// text store; duplicate detection here is per-shard only). Returns
    /// the global cluster id the chunk joined.
    pub fn insert_chunk(&self, id: u32, text: &str, emb: &[f32]) -> Result<u32> {
        let (global, split) = {
            let _serial = self.updates_serial.lock().unwrap();
            let topo = self.topo(); // stable under the updates mutex
            let target = self.route(emb)?;
            // Record-before-mutation: the routed insert hits the WAL
            // before the shard write lease. An append failure aborts
            // with every shard untouched; a crash after the append
            // replays the insert (which re-routes identically — routing
            // is a pure function of the structural state the log
            // rebuilds).
            self.wal_append(&WalOp::Insert {
                id,
                text: text.to_string(),
                emb: emb.to_vec(),
            })?;
            // Routing released its leases before this write acquire; the
            // shard re-probes internally under the write lease, and the
            // updates mutex keeps merges/splits/migrations from racing
            // the routing decision.
            let (local, n_before, n_after, parked_split) = {
                let mut guard = topo.shards[target].write().unwrap();
                let n_before = guard.clusters().n_clusters();
                let local = guard.insert_chunk(id, text, emb)?;
                let parked = guard.take_last_split();
                (local, n_before, guard.clusters().n_clusters(), parked)
            };
            topo.counters[target].inserts.fetch_add(1, Ordering::Relaxed);
            // Only a split touches the first level: it appends a fresh
            // local cluster (which needs a global id before anything can
            // probe for it) and rewrites the split cluster's centroid. A
            // plain insert changes neither centroids nor liveness, so
            // the probe snapshot stays valid and no ownership write (a
            // search drain point) is needed at all.
            let split = n_after > n_before;
            if split {
                self.register_new_locals(target, n_after);
                // Derived audit record with *global* ids: the split ran
                // inside the shard (whose index has no WAL); translate
                // the parked (parent, new) locals now that registration
                // allocated the new cluster's global id. Best-effort —
                // replay re-derives splits from the parent inserts.
                if self.wal.is_some() {
                    if let Some((pl, nl)) = parked_split {
                        let (pg, ng) = {
                            let own = self.ownership.read().unwrap();
                            (own.global_of(target, pl), own.global_of(target, nl))
                        };
                        if let (Some(pg), Some(ng)) = (pg, ng) {
                            let _ = self.wal_append(&WalOp::Split {
                                cluster: pg,
                                new_cluster: ng,
                            });
                        }
                    }
                }
            }
            let global = self
                .ownership
                .read()
                .unwrap()
                .global_of(target, local)
                .ok_or_else(|| anyhow::anyhow!("inserted cluster lost its owner"))?;
            (global, split)
        };
        if split {
            // Invalidate the lock-free probe snapshot (marked after the
            // write lease is released; the next probe rebuilds — queries
            // on the old snapshot behave like queries that arrived just
            // before this insert).
            self.table_stale.store(true, Ordering::Release);
        }
        self.note_update_op();
        Ok(global)
    }

    /// Remove a chunk (§5.4), write-leasing only the shard that owns it.
    /// Returns false if the chunk is unknown.
    ///
    /// A cluster that drains below
    /// [`MERGE_THRESHOLD`](crate::index::updates::MERGE_THRESHOLD)
    /// merges into its **global** nearest active neighbour — selected
    /// against the spliced probe snapshot, exactly the choice the
    /// unsharded oracle makes — not merely the nearest on its own shard.
    /// When the victim lives on another shard the merge executes as a
    /// composed migrate-then-merge (see
    /// [`ShardedEdgeIndex::merge_drained`]), so every removal sequence
    /// stays bit-identical to the single-shard oracle. A merge failure
    /// (e.g. a blob-store error) leaves both shards consistent with the
    /// chunk removed and the cluster still drained; the error propagates
    /// and the merge retries on the next structural touch (or via
    /// [`ShardedEdgeIndex::merge_drained`]).
    pub fn remove_chunk(&self, id: u32) -> Result<bool> {
        let removed = {
            let _serial = self.updates_serial.lock().unwrap();
            let topo = self.topo(); // stable under the updates mutex
            // Owner discovery is ownership-aware: a stale copy left by a
            // mid-flight migration never matches (and the updates mutex
            // means no migration is mid-flight now anyway).
            let owner = {
                let own = self.ownership.read().unwrap();
                (0..topo.len()).find(|&s| {
                    topo.shards[s]
                        .read()
                        .unwrap()
                        .cluster_of(id)
                        .is_some_and(|local| own.global_of(s, local).is_some())
                })
            };
            let Some(s) = owner else { return Ok(false) };
            // Record-before-mutation, once the chunk is known to exist.
            self.wal_append(&WalOp::Remove { id })?;
            let (removed, drained) = {
                let mut guard = topo.shards[s].write().unwrap();
                guard.remove_chunk_deferred(id)?
            };
            if removed {
                topo.counters[s].removes.fetch_add(1, Ordering::Relaxed);
                // A plain removal changes neither centroids nor liveness,
                // so the probe snapshot stays valid; only a merge (below)
                // touches the first level.
                if let Some(local) = drained {
                    if self.merge_drained_locked(s, local)? {
                        self.table_stale.store(true, Ordering::Release);
                    }
                }
            }
            removed
        };
        if removed {
            self.note_update_op();
        }
        Ok(removed)
    }

    /// The global merge victim a drained cluster would be absorbed into:
    /// the nearest active centroid across **all** shards, scored against
    /// the spliced probe snapshot in ascending global-id order with self
    /// and tombstones masked — bit-for-bit the choice
    /// [`EdgeIndex::merge_victim`] makes on the unsharded oracle, for
    /// any shard count and any ownership permutation (the snapshot is
    /// placement-independent). Returns None when at most one cluster is
    /// active (nothing to merge into; the oracle's guard).
    pub fn merge_victim(&self, global: u32) -> Result<Option<u32>> {
        let _serial = self.updates_serial.lock().unwrap();
        let Some((s, local)) = self.ownership.read().unwrap().owner_of(global) else {
            return Ok(None);
        };
        let centroid = self.with_shard(s, |e| e.clusters().centroids.row(local as usize).to_vec());
        self.select_merge_victim(global, &centroid)
    }

    /// Victim selection against the (current — caller holds the updates
    /// mutex, so no structural op is in flight) probe snapshot.
    fn select_merge_victim(&self, global: u32, centroid: &[f32]) -> Result<Option<u32>> {
        if self.active_clusters() <= 1 {
            return Ok(None);
        }
        let table = self.probe_table_current();
        // Under the updates mutex the snapshot is exactly current, so it
        // covers every global id ever allocated (ascending, ids[g] == g).
        anyhow::ensure!(
            (global as usize) < table.len(),
            "probe snapshot is missing cluster {global}"
        );
        let mut scores = table.masked_scores(&self.scorer, centroid)?;
        scores[global as usize] = f32::NEG_INFINITY;
        Ok(Some(table.ids[vecmath::argmax(&scores)]))
    }

    /// Merge the drained cluster `global` into its global nearest
    /// neighbour now, if it is still active and below the merge
    /// threshold. Returns true when a merge ran. This is the public
    /// retry hook for a merge that failed mid-flight (blob fault): the
    /// failed attempt left both shards consistent, and calling this
    /// completes the merge.
    pub fn merge_drained(&self, global: u32) -> Result<bool> {
        let merged = {
            let _serial = self.updates_serial.lock().unwrap();
            let Some((s, local)) = self.ownership.read().unwrap().owner_of(global) else {
                return Ok(false);
            };
            let drained = self.with_shard(s, |e| {
                e.active_flags()[local as usize]
                    && e.clusters().clusters[local as usize].len()
                        < crate::index::updates::MERGE_THRESHOLD
            });
            if !drained {
                return Ok(false);
            }
            self.merge_drained_locked(s, local)?
        };
        if merged {
            self.table_stale.store(true, Ordering::Release);
            self.note_update_op();
        }
        Ok(merged)
    }

    /// Route and execute the merge of a drained cluster (`shard`,
    /// `local`). Caller holds the updates mutex and no shard lease.
    /// Returns false when there is nothing to merge into (at most one
    /// active cluster — the drained cluster stays active, exactly like
    /// the oracle).
    fn merge_drained_locked(&self, shard: usize, local: u32) -> Result<bool> {
        let global = self
            .ownership
            .read()
            .unwrap()
            .global_of(shard, local)
            .ok_or_else(|| anyhow::anyhow!("drained cluster {shard}/{local} has no owner"))?;
        let centroid =
            self.with_shard(shard, |e| e.clusters().centroids.row(local as usize).to_vec());
        let Some(victim) = self.select_merge_victim(global, &centroid)? else {
            return Ok(false);
        };
        let (vs, vl) = self
            .ownership
            .read()
            .unwrap()
            .owner_of(victim)
            .ok_or_else(|| anyhow::anyhow!("merge victim {victim} has no owner"))?;
        // Derived audit record (global ids): replay re-derives the merge
        // — victim selection included — from the parent removes, so this
        // is best-effort bookkeeping. The cross-shard path deliberately
        // logs no `Migrate` either: its internal migration is part of
        // the same derived merge.
        let _ = self.wal_append(&WalOp::Merge {
            source: global,
            victim,
        });
        let topo = self.topo(); // stable under the updates mutex
        if vs == shard {
            // Victim on the same shard: the inline path under one write
            // lease (no search observes an intermediate state; blob
            // failures abort before any in-memory mutation).
            topo.shards[shard].write().unwrap().merge_into(local, vl)?;
        } else {
            self.merge_cross_shard(global, shard, local, vs, vl)?;
        }
        // The dead cluster's probe heat moves with its rows: the victim
        // absorbs it and the tombstone's counter clears (satellite
        // bugfix — orphaned heat used to survive merges forever).
        self.absorb_heat(global, victim);
        topo.counters[vs].merges.fetch_add(1, Ordering::Relaxed);
        Ok(true)
    }

    /// The composed cross-shard merge: migrate-then-merge, reusing the
    /// rebalancer's copy → flip → retire primitive and ordering every
    /// fallible blob operation before any irreversible mutation.
    ///
    /// ```text
    ///  [export]  source READ lease: centroid + members + dynamic rows +
    ///            gathered embeddings (no blob/cache payload — the merge
    ///            deletes both)                              (fallible)
    ///  [plan]    victim shard READ lease: post-merge accounting and the
    ///            combined blob, if one must exist           (fallible)
    ///  [unstore] source WRITE lease: drop the drained blob  (fallible —
    ///            a failure aborts with nothing changed; after it the
    ///            drained cluster regenerates instead of loading until
    ///            the flip, the same bounded window a stale probe
    ///            snapshot already implies)
    ///  [import]  victim-shard WRITE lease: adopt the drained cluster as
    ///            a fresh local copy (no blob, no cache)     (infallible)
    ///  [flip]    ownership WRITE lock: the global id maps to the victim
    ///            shard; the write acquire drains in-flight searches
    ///  [retire]  source WRITE lease: tombstone the orphan   (infallible:
    ///            its blob is already gone)
    ///  [merge]   victim-shard WRITE lease: victim blob transition
    ///            (fallible — a failure here aborts leaving a plain,
    ///            fully consistent migration; the still-drained cluster
    ///            retries as a same-shard merge), then the infallible
    ///            membership rewire
    /// ```
    ///
    /// At every instant a concurrent search sees each cluster on exactly
    /// one shard with blob/membership consistent, and a failure at any
    /// fallible step leaves `verify_integrity` green.
    fn merge_cross_shard(
        &self,
        global: u32,
        src: usize,
        local: u32,
        dest: usize,
        victim_local: u32,
    ) -> Result<()> {
        let topo = self.topo(); // stable under the updates mutex
        // Export + plan: read leases only, searches keep flowing.
        let (export, rows) = topo.shards[src].read().unwrap().export_for_merge(local)?;
        let extra = crate::index::updates::MergeExtra::from_export(&export, rows);
        let plan = topo.shards[dest].read().unwrap().plan_merge(victim_local, &extra)?;

        // Drop the drained cluster's blob while the source copy still
        // owns it — the last chance to abort with *zero* mutations.
        {
            let guard = topo.shards[src].write().unwrap();
            if let Some(blob) = guard.blob_store() {
                if blob.contains(local) {
                    blob.remove(local)?;
                }
            }
        }

        // Import → flip → retire: literally the migration tail a plain
        // `migrate_cluster` runs (shared `adopt_exported`), minus the
        // blob/cache payloads the export skipped.
        let new_local = self.adopt_exported(&export, global, src, local, dest)?;

        // Merge on the victim shard under one write lease: the fallible
        // blob transition first (an abort here leaves a plain migration
        // — both shards consistent, the merge retryable), then the
        // infallible membership rewire.
        let mut guard = topo.shards[dest].write().unwrap();
        guard.apply_merge_blob(&plan, None)?;
        guard.apply_merge_members(new_local, &plan);
        Ok(())
    }

    /// Count one completed structural update toward the periodic
    /// triggers — the heat decay (every `heat_decay_interval_ops`) and
    /// the rebalance round (every `rebalance_interval_ops`). Called
    /// after all locks are released (a round re-enters the updates
    /// mutex). Round errors are swallowed here — the serving update that
    /// triggered the round already succeeded; an explicit `rebalance` op
    /// surfaces them.
    fn note_update_op(&self) {
        // Recovery replay never triggers decay or rebalance rounds: the
        // trigger's migration choices depend on cache/heat state that is
        // defined cold after recovery, while replay must re-derive
        // exactly the structure the log records.
        if self.replaying.load(Ordering::Relaxed) {
            return;
        }
        if self.rebalance_every == 0 && self.heat_decay_every == 0 {
            return;
        }
        let n = self.update_ops.fetch_add(1, Ordering::Relaxed) + 1;
        // Decay before a coinciding rebalance round, so the round plans
        // on decayed (current-traffic) heat.
        if self.heat_decay_every != 0 && n % self.heat_decay_every as u64 == 0 {
            self.decay_heat();
        }
        if self.rebalance_every != 0 && n % self.rebalance_every as u64 == 0 {
            let _ = self.rebalance();
        }
    }

    /// Search then immediately commit every shard intent — the
    /// single-caller convenience path (tests, tools), mirroring
    /// [`EdgeIndex::search_and_commit`].
    pub fn search_and_commit(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let out = self.search(query, k)?;
        self.commit(&out.intents, out.ledger.retrieval());
        Ok(out)
    }

    /// Execute the per-shard cluster walks against the given topology
    /// snapshot, fanning all but the first group out to the pool.
    /// Returns `(shard, walk)` pairs in arbitrary order.
    fn run_walks(
        &self,
        topo: &Arc<Topology>,
        query: &[f32],
        work: Vec<(usize, Vec<(u32, u32)>)>,
        k: usize,
    ) -> Result<Vec<(usize, ClusterWalk)>> {
        let mut walks = Vec::with_capacity(work.len());
        if work.len() <= 1 || self.pool.workers() == 0 {
            for (s, group) in work {
                let walk = topo.shards[s].read().unwrap().search_clusters(query, &group, k)?;
                walks.push((s, walk));
            }
            return Ok(walks);
        }

        let query: Arc<Vec<f32>> = Arc::new(query.to_vec());
        let (tx, rx) = mpsc::channel::<Result<(usize, ClusterWalk)>>();
        let mut iter = work.into_iter();
        let first = iter.next().expect("work checked non-empty");
        let mut remote = 0usize;
        for (s, group) in iter {
            let shard = topo.shards[s].clone();
            let q = query.clone();
            let tx = tx.clone();
            let job: Job = Box::new(move || {
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    shard.read().unwrap().search_clusters(&q, &group, k)
                }));
                let msg = match res {
                    Ok(r) => r.map(|walk| (s, walk)),
                    Err(_) => Err(anyhow::anyhow!("shard {s} cluster walk panicked")),
                };
                let _ = tx.send(msg);
            });
            // A refused job (no workers / pool gone) runs on this thread;
            // its result still arrives through the channel.
            if let Err(SubmitError::Full(job) | SubmitError::Closed(job)) = self.pool.submit(job)
            {
                job();
            }
            remote += 1;
        }
        drop(tx);

        // Walk the first group on the calling thread while workers run
        // theirs, then collect.
        let (s, group) = first;
        let walk = topo.shards[s].read().unwrap().search_clusters(&query, &group, k)?;
        walks.push((s, walk));
        for _ in 0..remote {
            let pair = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("shard pool disconnected"))??;
            walks.push(pair);
        }
        Ok(walks)
    }

    /// Search using centroid scores a caller already computed against a
    /// [`ProbeTable`] snapshot of this index ([`crate::sched`] computes
    /// them for several queries in one fused `sim_{A}x{N}` call).
    /// Identical to [`VectorIndex::search`] whenever `scores` equals the
    /// snapshot's masked scores for this query — probe selection (ties
    /// included), the fan-out walks and the probe-order merge are the
    /// same code paths.
    pub fn search_scored(
        &self,
        query: &[f32],
        table: &ProbeTable,
        scores: &[f32],
        k: usize,
    ) -> Result<SearchOutcome> {
        anyhow::ensure!(
            scores.len() == table.len(),
            "probe scores ({}) must align with the probe table ({})",
            scores.len(),
            table.len()
        );
        let mut ledger = LatencyLedger::new();

        // One modeled charge for the whole (distributed but byte-
        // identical) centroid table.
        ledger.charge(
            Component::CentroidProbe,
            self.device.mem_scan_cost(table.centroid_bytes),
        );
        let probes = vecmath::top_k(scores, scores.len(), self.nprobe);

        // Group the probe list by owning shard, preserving each shard's
        // subsequence of the global probe order. The ownership read lock
        // is held from here through the cluster walks: the whole search
        // sees each cluster on exactly one shard, and a migration's
        // ownership flip (the write lock) waits for us before the source
        // copy is retired — which is what keeps concurrent searches
        // bit-identical to an unsharded index throughout a migration.
        // The topology snapshot is cloned *under* the ownership read
        // lock (reshard swaps run under the write lock), so the shard
        // indices the table resolves always index this snapshot.
        let own = self.ownership.read().unwrap();
        let topo = self.topo();
        let n_shards = topo.len();
        let mut probed = Vec::with_capacity(probes.len());
        let mut groups: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n_shards];
        for (pos, &(i, _)) in probes.iter().enumerate() {
            let g = table.ids[i];
            probed.push(g);
            let (s, l) = own
                .owner_of(g)
                .ok_or_else(|| anyhow::anyhow!("probed cluster {g} has no owner"))?;
            groups[s].push((pos as u32, l));
        }
        let work: Vec<(usize, Vec<(u32, u32)>)> = groups
            .into_iter()
            .enumerate()
            .filter(|(_, g)| !g.is_empty())
            .collect();
        for (s, group) in &work {
            topo.counters[*s]
                .probes
                .fetch_add(group.len() as u64, Ordering::Relaxed);
        }
        self.note_probes(&probed);

        // Fan the cluster walks out and merge.
        let mut walks = self.run_walks(&topo, query, work, k)?;
        drop(own);
        walks.sort_by_key(|&(s, _)| s); // deterministic intent order

        let mut events = SearchEvents::default();
        let mut intents = Vec::with_capacity(walks.len());
        let mut all_groups: Vec<ClusterHits> = Vec::new();
        let tracing = trace::enabled();
        let mut shard_walks = Vec::new();
        for (s, mut walk) in walks {
            if tracing {
                shard_walks.push(ShardWalk {
                    shard: s as u32,
                    clusters: walk.groups.len() as u32,
                    walk_ns: walk.walk_ns,
                    generated: walk.events.generated as u32,
                    loaded: walk.events.loaded as u32,
                    cache_hits: walk.events.cache_hits as u32,
                });
            }
            ledger.merge(&walk.ledger);
            events.generated += walk.events.generated;
            events.loaded += walk.events.loaded;
            events.cache_hits += walk.events.cache_hits;
            events.thrash_faults += walk.events.thrash_faults;
            let c = &topo.counters[s];
            c.cache_hits
                .fetch_add(walk.events.cache_hits as u64, Ordering::Relaxed);
            c.generated
                .fetch_add(walk.events.generated as u64, Ordering::Relaxed);
            c.loaded
                .fetch_add(walk.events.loaded as u64, Ordering::Relaxed);
            walk.intent.shard = s;
            intents.push(walk.intent);
            all_groups.append(&mut walk.groups);
        }

        // Merge the per-shard heaps: candidates re-sorted into global
        // probe order make the final top-k (ties included) identical to a
        // sequential walk's.
        all_groups.sort_by_key(|g| g.probe_pos);
        let all_hits: Vec<(u32, f32)> = all_groups.into_iter().flat_map(|g| g.hits).collect();
        let hits = vecmath::top_k_hits(all_hits, k);

        Ok(SearchOutcome {
            hits,
            ledger,
            probed,
            events,
            intents,
            shard_walks,
        })
    }

    // -----------------------------------------------------------------
    // Elastic topology: grow / shrink the live shard set
    // -----------------------------------------------------------------

    /// Change the live shard count to `target` (clamped to at least 1),
    /// online, under concurrent traffic. Growth installs fresh empty
    /// shards (clusters flow onto them through subsequent rebalance
    /// rounds — search results are placement-independent, so a grow
    /// alone changes nothing a query can observe); shrink drains every
    /// doomed shard through [`ShardedEdgeIndex::migrate_cluster`] and
    /// then retires it. Returns how many clusters the shrink migrated
    /// (0 for a grow).
    pub fn reshard(&self, target: usize) -> Result<crate::index::ReshardReport> {
        let target = target.max(1);
        let from = self.shards();
        let migrated = if target > from {
            self.grow_shards(target)?;
            0
        } else {
            self.shrink_shards(target)?
        };
        Ok(crate::index::ReshardReport {
            from,
            to: self.shards(),
            migrated,
        })
    }

    /// Grow the live shard set to `target` shards by building fresh
    /// empty [`EdgeIndex`]es from the retained build materials and
    /// installing them with one topology swap. The expensive
    /// construction runs outside every lock; the swap itself holds the
    /// updates mutex (no structural op mid-flight) and the ownership
    /// write lock (drains in-flight searches), so no search ever holds
    /// a pre-grow snapshot against post-grow ownership state. A no-op
    /// when `target` is not larger than the current count.
    pub fn grow_shards(&self, target: usize) -> Result<()> {
        anyhow::ensure!(target <= MAX_SHARDS, "at most {MAX_SHARDS} shards");
        let _round = self.rebalance_serial.lock().unwrap();
        let current = self.shards();
        if target <= current {
            return Ok(());
        }
        let dim = self.scorer.dim();
        // New shards get an even slice of the configured cache budget at
        // the post-grow count; existing shards keep the slice they were
        // built with (cache budgets are per-shard state, re-sliced only
        // on rebuild).
        let mut per_shard = self.retrieval_cfg.clone();
        per_shard.cache_capacity_bytes =
            (self.retrieval_cfg.cache_capacity_bytes / target as u64).max(1);
        let mut fresh = Vec::with_capacity(target - current);
        for i in current..target {
            let blob = if self.kind.uses_storage() {
                let dir = self
                    .blob_dir
                    .as_ref()
                    .ok_or_else(|| anyhow::anyhow!("selective storage requires a blob dir"))?;
                Some(BlobStore::open(&dir.join(format!("shard{i}")), dim)?)
            } else {
                None
            };
            let set = ClusterSet {
                centroids: EmbeddingMatrix::new(dim),
                clusters: Vec::new(),
            };
            let mut shard = EdgeIndex::build(
                self.kind,
                set,
                self.source.clone(),
                blob,
                self.scorer.clone(),
                self.memory.clone(),
                self.device.clone(),
                &per_shard,
                self.store_limit,
                self.slo,
            )?;
            shard.set_region_base((i as u32) << 24);
            fresh.push(Arc::new(RwLock::new(shard)));
        }
        // Install: updates mutex → ownership write → topology write —
        // exactly the swap ordering the lock hierarchy prescribes.
        let _serial = self.updates_serial.lock().unwrap();
        let mut own = self.ownership.write().unwrap();
        let old = self.topo();
        let mut shards = old.shards.clone();
        let mut counters = old.counters.clone();
        for s in fresh {
            shards.push(s);
            counters.push(Arc::new(ShardCounters::default()));
            own.locals.push(Vec::new());
        }
        *self.topology.write().unwrap() = Arc::new(Topology { shards, counters });
        Ok(())
    }

    /// Shrink the live shard set to `target` shards with a
    /// drain-then-retire protocol: every cluster owned by a doomed
    /// (trailing) shard migrates to the least-loaded surviving shard via
    /// the ordinary copy→flip→retire primitive — live traffic keeps
    /// flowing, and the oracle bit-equality argument is untouched
    /// because each step *is* a plain migration — then the doomed
    /// shards, verified empty under the updates mutex, are dropped with
    /// one topology swap (their `Arc`s free once in-flight walks
    /// finish). Tombstoned residents (merged-away clusters, which
    /// migration refuses) relocate through
    /// [`ShardedEdgeIndex::evacuate_tombstone`]. Concurrent structural
    /// ops can land new clusters on a doomed shard mid-drain, so the
    /// drain re-snapshots and retries until the retire check passes.
    /// Returns how many live clusters migrated.
    pub fn shrink_shards(&self, target: usize) -> Result<usize> {
        anyhow::ensure!(target >= 1, "at least one shard");
        let _round = self.rebalance_serial.lock().unwrap();
        let mut migrated = 0usize;
        for _attempt in 0..32 {
            // Snapshot: per-survivor row totals and the doomed residents.
            let (mut totals, doomed) = {
                let own = self.ownership.read().unwrap();
                let topo = self.topo();
                if target >= topo.len() {
                    return Ok(migrated);
                }
                let mut totals = vec![0u64; target];
                let mut doomed: Vec<(u32, u64, bool)> = Vec::new();
                for (s, shard) in topo.shards.iter().enumerate() {
                    let guard = shard.read().unwrap();
                    for (l, &g) in own.locals[s].iter().enumerate() {
                        if g == ORPHAN {
                            continue;
                        }
                        let rows = guard.clusters().clusters[l].len() as u64;
                        if s < target {
                            totals[s] += rows;
                        } else {
                            doomed.push((g, rows, guard.active_flags()[l]));
                        }
                    }
                }
                (totals, doomed)
            };
            // Drain, packing each cluster onto the currently
            // least-loaded survivor (ties → lower shard index).
            for &(g, rows, active) in &doomed {
                let dest = totals
                    .iter()
                    .enumerate()
                    .min_by_key(|&(s, &t)| (t, s))
                    .map(|(s, _)| s)
                    .expect("target >= 1");
                if active {
                    if self.migrate_cluster(g, dest)? {
                        migrated += 1;
                        totals[dest] += rows;
                    }
                    // false: merged away since the snapshot — the next
                    // attempt sees it as a tombstone and evacuates it.
                } else {
                    self.evacuate_tombstone(g, dest)?;
                }
            }
            // Retire: verify the doomed shards own nothing, then swap
            // them out. The updates mutex guarantees no structural op is
            // mid-flight; the ownership write lock drains searches.
            let _serial = self.updates_serial.lock().unwrap();
            let mut own = self.ownership.write().unwrap();
            let clean = own.locals[target..]
                .iter()
                .all(|slots| slots.iter().all(|&g| g == ORPHAN));
            if !clean {
                continue; // a racing structural op landed a cluster; re-drain
            }
            let old = self.topo();
            let shards = old.shards[..target].to_vec();
            let counters = old.counters[..target].to_vec();
            own.locals.truncate(target);
            *self.topology.write().unwrap() = Arc::new(Topology { shards, counters });
            drop(own);
            self.table_stale.store(true, Ordering::Release);
            return Ok(migrated);
        }
        anyhow::bail!("shard drain did not quiesce after 32 attempts")
    }

    /// Relocate a tombstoned slot (a merged-away cluster, which
    /// [`ShardedEdgeIndex::migrate_cluster`] refuses to move) to `dest`:
    /// import an empty tombstone copy of its centroid there — keeping
    /// the spliced probe snapshot byte-identical, since the splice reads
    /// exactly one centroid row per global id from its owner — flip
    /// ownership, and orphan the source slot. Shrink's drain uses this
    /// so a doomed shard can retire even when merges left tombstones on
    /// it; search results cannot change (tombstones are masked from
    /// every probe).
    fn evacuate_tombstone(&self, global: u32, dest: usize) -> Result<()> {
        let _serial = self.updates_serial.lock().unwrap();
        let topo = self.topo(); // stable under the updates mutex
        anyhow::ensure!(dest < topo.len(), "destination shard {dest} does not exist");
        let Some((src, local)) = self.ownership.read().unwrap().owner_of(global) else {
            return Ok(());
        };
        if src == dest {
            return Ok(());
        }
        let (still_tombstoned, centroid) = {
            let guard = topo.shards[src].read().unwrap();
            (
                !guard.active_flags()[local as usize],
                guard.clusters().centroids.row(local as usize).to_vec(),
            )
        };
        if !still_tombstoned {
            return Ok(()); // raced: a live cluster drains via migrate instead
        }
        let new_local = topo.shards[dest].write().unwrap().import_tombstone(&centroid);
        {
            let mut own = self.ownership.write().unwrap();
            own.owner[global as usize] = (dest as u32, new_local);
            own.locals[src][local as usize] = ORPHAN;
            debug_assert_eq!(own.locals[dest].len(), new_local as usize);
            own.locals[dest].push(global);
        }
        self.table_stale.store(true, Ordering::Release);
        Ok(())
    }
}

impl VectorIndex for ShardedEdgeIndex {
    fn kind(&self) -> IndexKind {
        self.kind
    }

    /// (1) centroid probe against the lock-free spliced snapshot (global
    /// cluster order, tombstones masked — probe selection and tie-breaks
    /// identical to the unsharded index, and **no shard lease is taken**,
    /// so a probing query never waits behind an in-flight insert), then
    /// (2..6) per-shard fan-out walks and the probe-order merge.
    fn search(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let table = self.probe_table_current();
        let scores = table.masked_scores(&self.scorer, query)?;
        self.search_scored(query, &table, &scores, k)
    }

    /// Commit each shard's intent independently: only that shard's
    /// controller/cache locks are taken, so commits for different shards
    /// (from this or other queries) never serialize on each other.
    fn commit(&self, intents: &[CacheIntent], retrieval: SimDuration) {
        let topo = self.topo();
        for intent in intents {
            // `get`, not indexing: a shrink may have retired the shard
            // this intent was recorded against between search and commit
            // — its cache died with it, so the intent just drops.
            let Some(shard) = topo.shards.get(intent.shard) else {
                continue;
            };
            shard.read().unwrap().commit_intent(intent, retrieval);
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> u64 {
        self.topo()
            .shards
            .iter()
            .map(|s| s.read().unwrap().resident_bytes())
            .sum()
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        ShardedEdgeIndex::cache_stats(self)
    }

    fn cache_used_bytes(&self) -> u64 {
        ShardedEdgeIndex::cache_used_bytes(self)
    }

    fn cached_clusters(&self) -> Vec<u32> {
        ShardedEdgeIndex::cached_clusters(self)
    }

    fn stored_clusters(&self) -> usize {
        ShardedEdgeIndex::stored_clusters(self)
    }

    fn stored_bytes(&self) -> u64 {
        ShardedEdgeIndex::stored_bytes(self)
    }

    fn threshold_ms(&self) -> f64 {
        ShardedEdgeIndex::threshold_ms(self)
    }

    fn pin_threshold(&mut self, threshold_ms: f64) {
        ShardedEdgeIndex::pin_threshold(self, threshold_ms)
    }

    fn shard_stats(&self) -> Option<Vec<ShardStats>> {
        Some(ShardedEdgeIndex::shard_stats(self))
    }

    fn rebalance(&self) -> Result<crate::index::RebalanceReport> {
        ShardedEdgeIndex::rebalance(self)
    }

    fn reshard(&self, target: usize) -> Result<crate::index::ReshardReport> {
        ShardedEdgeIndex::reshard(self, target)
    }

    fn supports_concurrent_updates(&self) -> bool {
        true
    }

    fn insert_chunk(&mut self, id: u32, text: &str, emb: &[f32]) -> Result<u32> {
        ShardedEdgeIndex::insert_chunk(self, id, text, emb)
    }

    fn remove_chunk(&mut self, id: u32) -> Result<bool> {
        ShardedEdgeIndex::remove_chunk(self, id)
    }

    fn insert_chunk_concurrent(&self, id: u32, text: &str, emb: &[f32]) -> Result<u32> {
        ShardedEdgeIndex::insert_chunk(self, id, text, emb)
    }

    fn remove_chunk_concurrent(&self, id: u32) -> Result<bool> {
        ShardedEdgeIndex::remove_chunk(self, id)
    }

    fn wal_checkpoint(&self) -> Result<()> {
        match &self.wal {
            Some(w) => w.checkpoint(),
            None => Ok(()),
        }
    }

    fn wal_stats(&self) -> Option<WalActivity> {
        self.wal.as_ref().map(|w| w.activity())
    }

    fn probe_rebuilds(&self) -> u64 {
        self.probe_rebuilds.load(Ordering::Relaxed)
    }

    fn probe_table(&self) -> Option<Arc<ProbeTable>> {
        Some(self.probe_table_current())
    }

    fn search_with_scores(
        &self,
        query: &[f32],
        table: &ProbeTable,
        scores: &[f32],
        k: usize,
    ) -> Result<SearchOutcome> {
        self.search_scored(query, table, scores, k)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetProfile;
    use crate::data::Corpus;
    use crate::embedding::{Embedder, EmbedderBackend};
    use crate::index::kmeans::{kmeans, KMeansConfig};
    use crate::index::shared_memory;
    use crate::testutil::shared_compute;

    struct Fixture {
        corpus: Corpus,
        emb: Arc<EmbeddingMatrix>,
        device: DeviceProfile,
        scorer: Scorer,
        embedder: Embedder,
    }

    fn fixture() -> Fixture {
        let profile = DatasetProfile::tiny();
        let corpus = Corpus::generate(&profile);
        let compute = shared_compute();
        let embedder = Embedder::new(compute.clone(), EmbedderBackend::Projection);
        let emb = Arc::new(embedder.embed_texts(&corpus.texts()).unwrap());
        Fixture {
            corpus,
            emb,
            device: DeviceProfile::jetson_orin_nano(),
            scorer: Scorer::new(compute),
            embedder,
        }
    }

    fn cluster_set(f: &Fixture) -> ClusterSet {
        let km = kmeans(
            &f.emb,
            &KMeansConfig {
                n_clusters: 8,
                iterations: 5,
                seed: 1,
                init: None,
            },
            &f.scorer,
        )
        .unwrap();
        ClusterSet::build(&f.corpus, km.centroids, &km.assignment, &f.device)
    }

    fn state_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("edgerag-shard-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn retrieval() -> RetrievalConfig {
        RetrievalConfig {
            nprobe: 4,
            ..Default::default()
        }
    }

    fn build_sharded(f: &Fixture, tag: &str, shards: usize) -> ShardedEdgeIndex {
        let dir = state_dir(tag);
        ShardedEdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(dir.as_path()),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &retrieval(),
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
            shards,
        )
        .unwrap()
    }

    fn build_edge(f: &Fixture, tag: &str) -> EdgeIndex {
        let dir = state_dir(tag);
        let blob = BlobStore::open(&dir, f.scorer.dim()).unwrap();
        EdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(blob),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &retrieval(),
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
        )
        .unwrap()
    }

    #[test]
    fn partition_covers_every_cluster() {
        let f = fixture();
        let set = cluster_set(&f);
        let total = set.n_clusters();
        let idx = build_sharded(&f, "part", 3);
        assert_eq!(idx.shards(), 3);
        let per_shard: usize = (0..3).map(|s| idx.with_shard(s, |e| e.clusters().n_clusters())).sum();
        assert_eq!(per_shard, total);
        // Every chunk is still owned by exactly one (global) cluster.
        for chunk in [0u32, 17, 101, 300] {
            let g = idx.cluster_of(chunk).expect("chunk routed");
            assert_eq!(idx.shard_of(g), g as usize % 3);
        }
    }

    #[test]
    fn single_shard_is_bit_identical_to_edge_index() {
        let f = fixture();
        let edge = build_edge(&f, "bit-e");
        let sharded = build_sharded(&f, "bit-s", 1);
        for i in [0usize, 17, 101, 300, 443] {
            let q = f.emb.row(i).to_vec();
            let a = edge.search(&q, 5).unwrap();
            let b = sharded.search(&q, 5).unwrap();
            assert_eq!(a.hits, b.hits, "query {i}");
            assert_eq!(a.probed, b.probed, "query {i}");
            assert_eq!(a.ledger.total(), b.ledger.total(), "query {i}");
            assert_eq!(a.events.generated, b.events.generated, "query {i}");
            assert_eq!(a.events.loaded, b.events.loaded, "query {i}");
            assert_eq!(b.intents.len(), 1);
            assert_eq!(b.intents[0].shard, 0);
        }
    }

    #[test]
    fn four_shards_identical_topk_and_admissions() {
        // The satellite equivalence property at unit scale: same corpus,
        // same queries → identical top-k and identical per-cluster cache
        // admissions for shards=1 vs shards=4 (thresholds pinned so the
        // per-shard feedback loops cannot diverge).
        let f = fixture();
        let one = build_sharded(&f, "eq1", 1);
        let four = build_sharded(&f, "eq4", 4);
        one.pin_threshold(0.0);
        four.pin_threshold(0.0);
        for i in 0..16usize {
            let q = f.emb.row(i * 30).to_vec();
            let a = one.search_and_commit(&q, 5).unwrap();
            let b = four.search_and_commit(&q, 5).unwrap();
            assert_eq!(a.hits, b.hits, "query {i}");
            assert_eq!(a.events.generated, b.events.generated, "query {i}");
            assert_eq!(a.events.cache_hits, b.events.cache_hits, "query {i}");
        }
        assert_eq!(one.cached_clusters(), four.cached_clusters());
    }

    #[test]
    fn insert_and_remove_route_to_owning_shard() {
        let f = fixture();
        let idx = build_sharded(&f, "ins", 4);
        let text = "a fresh shard-routed document with marker tokens zzshard yyshard";
        let emb = f.embedder.embed_one(text).unwrap();
        let id = f.corpus.len() as u32 + 7;
        let expected_shard = idx.route(&emb).unwrap();
        let cluster = idx.insert_chunk(id, text, &emb).unwrap();
        assert_eq!(idx.shard_of(cluster), expected_shard);
        assert_eq!(idx.cluster_of(id), Some(cluster));
        let out = idx.search_and_commit(&emb, 3).unwrap();
        assert_eq!(out.hits[0].0, id, "hits: {:?}", out.hits);
        let stats = idx.shard_stats();
        assert_eq!(stats[expected_shard].inserts, 1);
        assert!(idx.remove_chunk(id).unwrap());
        assert_eq!(idx.cluster_of(id), None);
        assert!(!idx.remove_chunk(id).unwrap(), "second remove is a no-op");
    }

    #[test]
    fn insert_does_not_block_readers_of_other_shards() {
        // The tentpole overlap property, made deterministic: hold a read
        // lease on a shard the insert does NOT own; the insert must still
        // complete.
        let f = fixture();
        let idx = Arc::new(build_sharded(&f, "overlap", 4));
        let text = "overlap probe document zzoverlap";
        let emb = f.embedder.embed_one(text).unwrap();
        let target = idx.route(&emb).unwrap();
        let other = (target + 1) % idx.shards();
        let id = f.corpus.len() as u32 + 11;
        idx.with_shard(other, |_held| {
            let (tx, rx) = mpsc::channel();
            let idx2 = idx.clone();
            let emb2 = emb.clone();
            let text2 = text.to_string();
            std::thread::spawn(move || {
                let _ = tx.send(idx2.insert_chunk(id, &text2, &emb2).map(|_| ()));
            });
            rx.recv_timeout(std::time::Duration::from_secs(10))
                .expect("insert must not block on an unrelated shard's read lease")
                .expect("insert succeeds");
        });
        assert_eq!(idx.cluster_of(id).map(|g| idx.shard_of(g)), Some(target));
    }

    #[test]
    fn concurrent_queries_and_inserts_stay_consistent() {
        let f = fixture();
        let idx = build_sharded(&f, "conc", 4);
        let queries: Vec<Vec<f32>> = (0..8).map(|i| f.emb.row(i * 50).to_vec()).collect();
        let serial: Vec<Vec<u32>> = queries
            .iter()
            .map(|q| idx.search(q, 5).unwrap().hits.iter().map(|h| h.0).collect())
            .collect();
        let base = f.corpus.len() as u32 + 100;
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let idx = &idx;
                let queries = &queries;
                scope.spawn(move || {
                    for _ in 0..3 {
                        for q in queries {
                            // Concurrent inserts may add hits but must
                            // never corrupt a search.
                            let out = idx.search_and_commit(q, 5).unwrap();
                            assert!(!out.hits.is_empty());
                        }
                    }
                });
            }
            let idx = &idx;
            let embedder = &f.embedder;
            scope.spawn(move || {
                for i in 0..10u32 {
                    let text = format!("concurrent insert number {i} marker zzconc{i}");
                    let emb = embedder.embed_one(&text).unwrap();
                    idx.insert_chunk(base + i, &text, &emb).unwrap();
                }
            });
        });
        // After the dust settles: serial agreement for the original
        // corpus' queries still holds on the top hit (inserted docs can
        // only displace weaker candidates), and every insert is routed.
        for (i, q) in queries.iter().enumerate() {
            let ids: Vec<u32> = idx.search(q, 5).unwrap().hits.iter().map(|h| h.0).collect();
            assert_eq!(ids[0], serial[i][0], "query {i} top hit changed");
        }
        let total_inserts: u64 = idx.shard_stats().iter().map(|s| s.inserts).sum();
        assert_eq!(total_inserts, 10);
        for i in 0..10u32 {
            assert!(idx.cluster_of(base + i).is_some(), "insert {i} lost");
        }
    }

    #[test]
    fn probe_needs_no_shard_lease() {
        // ROADMAP deferred item (a): the centroid probe reads only the
        // lock-free snapshot — it must complete (and select exactly the
        // probes a full search selects) even while EVERY shard's write
        // lease is held by an in-flight structural update.
        let f = fixture();
        let idx = build_sharded(&f, "probe-free", 4);
        let q = f.emb.row(10).to_vec();
        let expect = idx.search(&q, 5).unwrap();
        let topo = idx.topo();
        let guards: Vec<_> = topo.shards.iter().map(|s| s.write().unwrap()).collect();
        let table = VectorIndex::probe_table(&idx).unwrap();
        let scores = table.masked_scores(&f.scorer, &q).unwrap();
        let probes = vecmath::top_k(&scores, scores.len(), 4);
        drop(guards);
        let probed: Vec<u32> = probes.iter().map(|&(i, _)| table.ids[i]).collect();
        assert_eq!(probed, expect.probed, "snapshot probe diverged");
    }

    #[test]
    fn remove_refreshes_probe_snapshot() {
        // Tombstoning a cluster must propagate into the lock-free
        // snapshot so later probes mask it out.
        let f = fixture();
        let idx = build_sharded(&f, "probe-refresh", 2);
        let before = VectorIndex::probe_table(&idx).unwrap();
        let live_before = before.active.iter().filter(|&&a| a).count();
        // Drain one cluster below MERGE_THRESHOLD to force a merge.
        let victim = idx.with_shard(0, |e| e.clusters().clusters[0].chunk_ids.clone());
        for &chunk in victim.iter().take(victim.len().saturating_sub(1)) {
            idx.remove_chunk(chunk).unwrap();
        }
        let after = VectorIndex::probe_table(&idx).unwrap();
        let live_after = after.active.iter().filter(|&&a| a).count();
        assert!(
            live_after < live_before,
            "merge must tombstone a cluster in the snapshot \
             ({live_before} -> {live_after})"
        );
    }

    #[test]
    fn migration_preserves_results_and_moves_resources() {
        // Move a cluster between shards and require: identical search
        // results (hits, probes, modeled latency), the cache entry and
        // blob travel with it, and every cross-shard invariant holds.
        let f = fixture();
        let idx = build_sharded(&f, "mig", 4);
        idx.pin_threshold(0.0);
        let queries: Vec<Vec<f32>> = (0..8).map(|i| f.emb.row(i * 60).to_vec()).collect();
        // Warm the caches so migrated clusters carry cache entries.
        for q in &queries {
            idx.search_and_commit(q, 5).unwrap();
        }
        let before: Vec<SearchOutcome> =
            queries.iter().map(|q| idx.search(q, 5).unwrap()).collect();
        let cached_before = idx.cached_clusters();
        let stored_before = idx.stored_clusters();

        // Migrate one cached cluster and one stored cluster (when they
        // exist) plus an arbitrary one, each to the next shard over.
        let mut moved = Vec::new();
        let mut targets: Vec<u32> = cached_before.iter().take(1).copied().collect();
        targets.push(before[0].probed[0]);
        for g in targets {
            let from = idx.shard_of(g);
            let to = (from + 1) % idx.shards();
            if idx.migrate_cluster(g, to).unwrap() {
                moved.push((g, from, to));
                assert_eq!(idx.shard_of(g), to, "ownership flipped");
            }
            idx.verify_integrity().unwrap();
        }
        assert!(!moved.is_empty(), "at least one migration must run");

        // Search results are unchanged — same hits, probes and modeled
        // device time (the spliced probe table is byte-identical).
        for (q, b) in queries.iter().zip(&before) {
            let a = idx.search(q, 5).unwrap();
            assert_eq!(a.hits, b.hits);
            assert_eq!(a.probed, b.probed);
            assert_eq!(a.ledger.total(), b.ledger.total());
        }
        // Cache entries and blobs moved, not dropped (modulo per-shard
        // capacity: the destination slice may decline an oversized
        // entry, which the tiny fixture never produces).
        assert_eq!(idx.cached_clusters(), cached_before);
        assert_eq!(idx.stored_clusters(), stored_before);
        let stats = idx.shard_stats();
        let (total_in, total_out): (u64, u64) = stats
            .iter()
            .fold((0, 0), |(i, o), s| (i + s.migrated_in, o + s.migrated_out));
        assert_eq!(total_in as usize, moved.len());
        assert_eq!(total_out as usize, moved.len());
    }

    #[test]
    fn migrated_cluster_serves_updates_and_repeat_migrations() {
        // A migrated cluster keeps working as an update target, and can
        // migrate again (ping-pong) without losing chunks.
        let f = fixture();
        let idx = build_sharded(&f, "mig2", 3);
        let text = "migration target document zzmigrate yymigrate";
        let emb = f.embedder.embed_one(text).unwrap();
        let id = f.corpus.len() as u32 + 21;
        let g = idx.insert_chunk(id, text, &emb).unwrap();
        for round in 0..4 {
            let to = (idx.shard_of(g) + 1) % idx.shards();
            assert!(idx.migrate_cluster(g, to).unwrap(), "round {round}");
            assert_eq!(idx.cluster_of(id), Some(g), "round {round}");
            let out = idx.search(&emb, 3).unwrap();
            assert_eq!(out.hits[0].0, id, "round {round}: {:?}", out.hits);
            idx.verify_integrity().unwrap();
        }
        // Remove still finds the (twice-moved) owner.
        assert!(idx.remove_chunk(id).unwrap());
        assert_eq!(idx.cluster_of(id), None);
        idx.verify_integrity().unwrap();
    }

    #[test]
    fn rebalance_reduces_skewed_spread() {
        // Adversarial skew: shove every cluster onto shard 0, then let
        // bounded rebalance rounds equalize the row load.
        let f = fixture();
        let idx = build_sharded(&f, "skew", 4);
        let globals: Vec<u32> = idx
            .cluster_loads()
            .iter()
            .flatten()
            .map(|c| c.global)
            .collect();
        for g in globals {
            idx.migrate_cluster(g, 0).unwrap();
        }
        idx.verify_integrity().unwrap();
        let before = idx.load_spread();
        assert!(before > 0, "skew must show as spread");
        let max_load = idx
            .cluster_loads()
            .iter()
            .flatten()
            .map(|c| c.load())
            .max()
            .unwrap();
        let mut rounds = 0;
        loop {
            let r = idx.rebalance().unwrap();
            assert!(
                r.migrated + r.skipped <= idx.max_migrations,
                "round bound violated: {r:?}"
            );
            assert!(r.spread_after <= r.spread_before, "{r:?}");
            idx.verify_integrity().unwrap();
            rounds += 1;
            if r.migrated == 0 || rounds >= 16 {
                break;
            }
        }
        // Guaranteed endpoint of the greedy equalizer: either the spread
        // halved, or it is pinned by indivisibly large clusters (a stuck
        // donor's every cluster exceeds half the remaining gap).
        let after = idx.load_spread();
        assert!(
            after < before && after <= (before / 2).max(2 * max_load),
            "spread {before} -> {after} (max cluster load {max_load}) \
             after {rounds} rounds"
        );
        // Results still match a fresh un-skewed build query for query.
        let fresh = build_sharded(&f, "skew-fresh", 4);
        for i in [0usize, 17, 101, 300] {
            let q = f.emb.row(i).to_vec();
            assert_eq!(
                idx.search(&q, 5).unwrap().hits,
                fresh.search(&q, 5).unwrap().hits,
                "query {i}"
            );
        }
    }

    #[test]
    fn rejects_too_many_shards() {
        let f = fixture();
        let dir = state_dir("max");
        let err = ShardedEdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(&f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(dir.as_path()),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &retrieval(),
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
            MAX_SHARDS + 1,
        );
        assert!(err.is_err());
    }

    #[test]
    fn grow_and_shrink_preserve_results_under_repeat_queries() {
        // The elastic tentpole at unit scale: grow 2→4, spread clusters
        // onto the new shards, shrink 4→1 — search results (hits,
        // probes, modeled latency) must be bit-identical throughout,
        // because every step is composed from the migrate primitive.
        let f = fixture();
        let idx = build_sharded(&f, "elastic", 2);
        let queries: Vec<Vec<f32>> = (0..8).map(|i| f.emb.row(i * 55).to_vec()).collect();
        let before: Vec<SearchOutcome> =
            queries.iter().map(|q| idx.search(q, 5).unwrap()).collect();

        let r = idx.reshard(4).unwrap();
        assert_eq!((r.from, r.to, r.migrated), (2, 4, 0));
        assert_eq!(idx.shards(), 4);
        idx.verify_integrity().unwrap();
        for (q, b) in queries.iter().zip(&before) {
            let a = idx.search(q, 5).unwrap();
            assert_eq!(a.hits, b.hits);
            assert_eq!(a.probed, b.probed);
            assert_eq!(a.ledger.total(), b.ledger.total());
        }

        // The new shards are live migration targets.
        let g = before[0].probed[0];
        assert!(idx.migrate_cluster(g, 3).unwrap());
        assert_eq!(idx.shard_of(g), 3);
        idx.verify_integrity().unwrap();

        let r = idx.reshard(1).unwrap();
        assert_eq!((r.from, r.to), (4, 1));
        assert!(r.migrated > 0, "the drain must move the trailing shards' clusters");
        assert_eq!(idx.shards(), 1);
        idx.verify_integrity().unwrap();
        for (q, b) in queries.iter().zip(&before) {
            let a = idx.search(q, 5).unwrap();
            assert_eq!(a.hits, b.hits);
            assert_eq!(a.probed, b.probed);
            assert_eq!(a.ledger.total(), b.ledger.total());
        }
    }

    #[test]
    fn shrink_evacuates_tombstoned_slots() {
        // Merge-away a cluster owned by the shard about to retire, then
        // shrink: the tombstone (which migrate_cluster refuses to move)
        // must relocate rather than wedge the drain.
        let f = fixture();
        let idx = build_sharded(&f, "shrink-tomb", 2);
        // Global 1 lives at shard 1 (round-robin); drain it fully so it
        // merges into its nearest neighbour.
        let chunks = idx.with_shard(1, |e| e.clusters().clusters[0].chunk_ids.clone());
        for &c in &chunks {
            idx.remove_chunk(c).unwrap();
        }
        idx.verify_integrity().unwrap();
        let before: Vec<SearchOutcome> = (0..6)
            .map(|i| idx.search(&f.emb.row(i * 40).to_vec(), 5).unwrap())
            .collect();
        idx.reshard(1).unwrap();
        assert_eq!(idx.shards(), 1);
        idx.verify_integrity().unwrap();
        for (i, b) in before.iter().enumerate() {
            let a = idx.search(&f.emb.row(i * 40).to_vec(), 5).unwrap();
            assert_eq!(a.hits, b.hits, "query {i}");
            assert_eq!(a.probed, b.probed, "query {i}");
        }
    }

    #[test]
    fn merge_absorbs_probe_heat_and_tombstones_report_none() {
        // Satellite regression: a merged-away cluster's heat must move
        // to its victim and clear — no orphaned heat, no tombstones in
        // the heat table or any shard's hot_clusters rows.
        let f = fixture();
        let idx = build_sharded(&f, "heat-absorb", 2);
        // Heat every cluster a little, then heat the doomed cluster
        // specifically through its own centroid.
        for i in 0..6usize {
            idx.search(&f.emb.row(i * 70).to_vec(), 5).unwrap();
        }
        let doomed: u32 = 1;
        let centroid = idx.with_shard(1, |e| e.clusters().centroids.row(0).to_vec());
        idx.search(&centroid, 5).unwrap();
        let heat_of = |table: &[(u32, u64)], g: u32| {
            table.iter().find(|&&(id, _)| id == g).map_or(0, |&(_, n)| n)
        };
        let before = idx.cluster_probe_heat();
        assert!(heat_of(&before, doomed) > 0, "doomed cluster must be hot");
        let victim = idx
            .merge_victim(doomed)
            .unwrap()
            .expect("a victim exists among 8 clusters");
        let chunks = idx.with_shard(1, |e| e.clusters().clusters[0].chunk_ids.clone());
        for &c in &chunks {
            idx.remove_chunk(c).unwrap();
        }
        let after = idx.cluster_probe_heat();
        assert_eq!(heat_of(&after, doomed), 0, "dead cluster's heat must clear");
        assert_eq!(
            heat_of(&after, victim),
            heat_of(&before, victim) + heat_of(&before, doomed),
            "victim absorbs the dead cluster's heat"
        );
        for s in idx.shard_stats() {
            assert!(
                s.hot_clusters.iter().all(|&(g, _)| g != doomed),
                "tombstoned cluster surfaced in shard {}'s hot list",
                s.shard
            );
        }
    }

    #[test]
    fn heat_decay_halves_counters_and_prunes_affinity() {
        let f = fixture();
        let dir = state_dir("decay");
        let idx = ShardedEdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(&f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(dir.as_path()),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &RetrievalConfig {
                nprobe: 4,
                heat_decay_interval_ops: 1,
                rebalance: false,
                ..Default::default()
            },
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
            2,
        )
        .unwrap();
        // Two identical searches: every probed cluster at heat 2, every
        // co-probe pair at 2; plus one single search elsewhere at 1.
        let q = f.emb.row(5).to_vec();
        idx.search(&q, 5).unwrap();
        idx.search(&q, 5).unwrap();
        let probed = idx.search(&f.emb.row(400).to_vec(), 5).unwrap().probed;
        assert_eq!(probed.len(), 4);
        let heat_before = idx.cluster_probe_heat();
        let aff_before = idx.cluster_affinity();
        assert!(!aff_before.is_empty(), "nprobe=4 searches must record pairs");
        // One structural op fires the decay (interval 1).
        let text = "decay trigger document zzdecay";
        let emb = f.embedder.embed_one(text).unwrap();
        idx.insert_chunk(f.corpus.len() as u32 + 31, text, &emb).unwrap();
        let heat_after = idx.cluster_probe_heat();
        let aff_after = idx.cluster_affinity();
        for &(g, n) in &heat_before {
            let now = heat_after.iter().find(|&&(id, _)| id == g).map_or(0, |&(_, v)| v);
            assert_eq!(now, n / 2, "heat[{g}] must halve ({n} -> {now})");
        }
        for &(pair, n) in &aff_before {
            let now = aff_after.iter().find(|&&(p, _)| p == pair).map_or(0, |&(_, v)| v);
            assert_eq!(now, n / 2, "affinity[{pair:?}] must halve ({n} -> {now})");
        }
        assert!(
            aff_after.iter().all(|&(_, v)| v > 0),
            "decay must prune zeroed affinity edges"
        );
    }

    #[test]
    fn co_probe_pairs_are_normalized_and_bounded() {
        let f = fixture();
        let idx = build_sharded(&f, "aff", 2);
        let out = idx.search(&f.emb.row(3).to_vec(), 5).unwrap();
        assert_eq!(out.probed.len(), 4);
        let aff = idx.cluster_affinity();
        // One search with nprobe=4 yields exactly C(4,2) = 6 pairs.
        assert_eq!(aff.len(), 6);
        for &((a, b), n) in &aff {
            assert!(a < b, "pair keys are normalized low/high");
            assert!(n >= 1);
            assert!(out.probed.contains(&a) && out.probed.contains(&b));
        }
        assert!(aff.len() <= MAX_AFFINITY_PAIRS);
    }
}
