//! The sharded EdgeRAG index: clusters partitioned across `N`
//! independently locked shards so one query fans its probed clusters out
//! to a scoped worker pool and structural updates stall only the owning
//! shard.
//!
//! ## Why shard
//!
//! EdgeRAG's retrieval splits into a centroid probe plus per-cluster
//! work (load / cache peek / online generation, then an in-cluster
//! scan). The per-cluster stage is embarrassingly parallel, but a
//! single [`EdgeIndex`] walks all probed clusters on one thread and all
//! queries share one cache lock, one threshold lock and one write lease
//! for updates. [`ShardedEdgeIndex`] partitions clusters round-robin
//! across `N` shards — each shard is a complete [`EdgeIndex`] over its
//! subset, with its **own** cost-aware cache, adaptive-threshold
//! controller and update generation behind its **own** `RwLock` — so:
//!
//! * a query's probed clusters execute as per-shard cluster walks, in
//!   parallel on the shard pool, and the per-shard top-k heaps merge
//!   back in probe order;
//! * an online insert/remove takes only the owning shard's write lease:
//!   cluster walks and intent commits touching other shards proceed
//!   concurrently. (The centroid-probe step still reads every shard's
//!   centroids one lock at a time, so a *newly arriving* query can wait
//!   behind an in-flight structural update on that one shard during its
//!   probe — bounded by the update, never by the whole index;
//!   lifting the centroid table out of the shard lease is a ROADMAP
//!   item);
//! * each shard's deferred [`CacheIntent`] commits independently under
//!   that shard's locks.
//!
//! ## Equivalence with the unsharded index
//!
//! Sharding must not change retrieval results. Three mechanisms make the
//! sharded walk reproduce the sequential one exactly:
//!
//! 1. probes are selected from a **global** score table (per-shard
//!    centroid scores spliced back into global cluster order), so the
//!    probed set and order match the unsharded probe;
//! 2. every shard runs the *same* cluster-walk code
//!    ([`EdgeIndex::search_clusters`]) over its subsequence of the probe
//!    order, tagging each cluster's candidates with their global probe
//!    position;
//! 3. the merge re-sorts candidate groups by probe position before the
//!    final top-k, recreating the exact candidate order (and therefore
//!    the exact ties) a sequential walk produces.
//!
//! With `shards = 1` the whole path degenerates to a single
//! [`EdgeIndex`] walk and is bit-identical to it. With `shards > 1` the
//! top-k ids/scores are still identical; only cache *capacity placement*
//! changes (the byte budget splits evenly across shards, and each shard
//! adapts its own threshold from the queries that touch it).
//!
//! ## Cluster ids
//!
//! Shards use dense local cluster ids internally. The global id of local
//! cluster `l` in shard `s` is `l × n_shards + s` (so the initial
//! round-robin partition maps global id `g` to shard `g % n_shards`,
//! local `g / n_shards`, and splits allocate fresh globally unique ids).
//! [`SearchOutcome::probed`] and the cluster ids returned by
//! [`ShardedEdgeIndex::insert_chunk`] are global ids.
//!
//! ## Locking
//!
//! Lock order is strictly `shard RwLock → controller → cache → memory
//! model`, and no thread ever holds two shard locks at once (probing and
//! routing visit shards sequentially, one read lock at a time; fan-out
//! workers each take exactly one). See `docs/ARCHITECTURE.md` for the
//! full hierarchy including the engine lease above this one.

use std::path::Path;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Mutex, RwLock};

use anyhow::Result;

use crate::cache::CacheStats;
use crate::config::{DeviceProfile, IndexKind, RetrievalConfig};
use crate::index::edge::{ClusterHits, ClusterWalk};
use crate::index::{
    CacheIntent, ClusterMeta, ClusterSet, EdgeIndex, EmbedSource, Scorer, SearchEvents,
    SearchOutcome, SharedMemory, VectorIndex,
};
use crate::simtime::{Component, LatencyLedger, SimDuration};
use crate::storage::BlobStore;
use crate::vecmath::{self, EmbeddingMatrix};

/// Hard ceiling on the shard count: shard `i` namespaces its memory-model
/// regions at `i << 24`, leaving 24 bits of local cluster ids per shard.
pub const MAX_SHARDS: usize = 256;

// ---------------------------------------------------------------------------
// Shard worker pool
// ---------------------------------------------------------------------------

type Job = Box<dyn FnOnce() + Send + 'static>;

/// Persistent pool executing per-(query, shard) cluster walks. Workers
/// are plain threads over one shared queue; any worker may serve any
/// shard (shard state is behind per-shard `RwLock`s, and walks only take
/// read locks, so two workers can walk the same shard concurrently).
/// Threads are detached and exit when the pool (and with it the sender)
/// drops.
struct ShardPool {
    /// `Mutex` so the pool is `Sync` on every supported toolchain.
    tx: Mutex<mpsc::Sender<Job>>,
    workers: usize,
}

impl ShardPool {
    fn new(workers: usize) -> ShardPool {
        let (tx, rx) = mpsc::channel::<Job>();
        let rx = Arc::new(Mutex::new(rx));
        for i in 0..workers {
            let rx = rx.clone();
            std::thread::Builder::new()
                .name(format!("edgerag-shard-{i}"))
                .spawn(move || loop {
                    let job = match rx.lock() {
                        Ok(guard) => match guard.recv() {
                            Ok(job) => job,
                            Err(_) => break, // pool dropped: drain and exit
                        },
                        Err(_) => break, // queue mutex poisoned: stop cleanly
                    };
                    // Panic isolation: a panicking walk fails only its own
                    // query (the caller sees the reply channel close), not
                    // the pool.
                    let _ = std::panic::catch_unwind(std::panic::AssertUnwindSafe(job));
                })
                .expect("spawning shard worker thread");
        }
        ShardPool {
            tx: Mutex::new(tx),
            workers,
        }
    }

    /// Try to enqueue; hands the job back if the pool has no workers (or
    /// its queue is gone) so the caller can run it inline.
    fn submit(&self, job: Job) -> std::result::Result<(), Job> {
        if self.workers == 0 {
            return Err(job);
        }
        match self.tx.lock() {
            Ok(tx) => tx.send(job).map_err(|e| e.0),
            Err(_) => Err(job),
        }
    }
}

// ---------------------------------------------------------------------------
// Per-shard serving counters
// ---------------------------------------------------------------------------

#[derive(Debug, Default)]
struct ShardCounters {
    probes: AtomicU64,
    cache_hits: AtomicU64,
    generated: AtomicU64,
    loaded: AtomicU64,
    inserts: AtomicU64,
    removes: AtomicU64,
}

/// One shard's serving statistics snapshot (the `stats` endpoint's
/// per-shard rows).
#[derive(Debug, Clone, Default)]
pub struct ShardStats {
    /// Shard index.
    pub shard: usize,
    /// Active (non-tombstone) clusters currently owned by this shard.
    pub clusters: usize,
    /// Probed clusters routed to this shard so far.
    pub probes: u64,
    /// Embedding-cache hits served by this shard.
    pub cache_hits: u64,
    /// Clusters this shard generated online.
    pub generated: u64,
    /// Clusters this shard loaded from its blob store.
    pub loaded: u64,
    /// Online insertions routed to this shard.
    pub inserts: u64,
    /// Online removals routed to this shard.
    pub removes: u64,
    /// This shard's current adaptive caching threshold (ms).
    pub threshold_ms: f64,
    /// Bytes resident in this shard's embedding cache.
    pub cache_used_bytes: u64,
}

// ---------------------------------------------------------------------------
// The sharded index
// ---------------------------------------------------------------------------

/// Clusters partitioned across `N` independently locked [`EdgeIndex`]
/// shards (see the module docs for the design and equivalence argument).
pub struct ShardedEdgeIndex {
    kind: IndexKind,
    /// `Arc` so fan-out jobs on the pool can borrow shards without tying
    /// their lifetimes to the calling query.
    shards: Arc<Vec<RwLock<EdgeIndex>>>,
    counters: Vec<ShardCounters>,
    nprobe: usize,
    device: DeviceProfile,
    pool: ShardPool,
}

impl ShardedEdgeIndex {
    /// Partition `clusters` round-robin across `shards` shards and build
    /// one [`EdgeIndex`] per shard. The cache byte budget in `retrieval`
    /// splits evenly; `blob_dir` (required when `kind` uses selective
    /// storage) gets one `shard{i}` subdirectory per shard.
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        kind: IndexKind,
        clusters: ClusterSet,
        source: EmbedSource,
        blob_dir: Option<&Path>,
        scorer: Scorer,
        memory: SharedMemory,
        device: DeviceProfile,
        retrieval: &RetrievalConfig,
        store_limit: SimDuration,
        slo: SimDuration,
        shards: usize,
    ) -> Result<Self> {
        let k = shards.max(1);
        anyhow::ensure!(k <= MAX_SHARDS, "at most {MAX_SHARDS} shards");
        anyhow::ensure!(
            clusters.n_clusters() < (1 << 24),
            "cluster ids must fit the 24-bit per-shard namespace"
        );
        let dim = clusters.centroids.dim;

        // Round-robin partition: global cluster `g` → shard `g % k`,
        // local id `g / k`. Round-robin (rather than contiguous ranges)
        // balances the tail-heavy cluster-size distribution in
        // expectation.
        let mut parts: Vec<(EmbeddingMatrix, Vec<ClusterMeta>)> = (0..k)
            .map(|_| (EmbeddingMatrix::new(dim), Vec::new()))
            .collect();
        for (g, meta) in clusters.clusters.iter().enumerate() {
            let (centroids, metas) = &mut parts[g % k];
            centroids.push(clusters.centroids.row(g));
            metas.push(ClusterMeta {
                id: metas.len() as u32,
                chunk_ids: meta.chunk_ids.clone(),
                chars: meta.chars,
                gen_cost: meta.gen_cost,
            });
        }

        // Each shard gets an even slice of the cache byte budget.
        let mut per_shard = retrieval.clone();
        per_shard.cache_capacity_bytes = (retrieval.cache_capacity_bytes / k as u64).max(1);

        let mut built = Vec::with_capacity(k);
        for (i, (centroids, metas)) in parts.into_iter().enumerate() {
            let set = ClusterSet {
                centroids,
                clusters: metas,
            };
            let blob = if kind.uses_storage() {
                let dir = blob_dir
                    .ok_or_else(|| anyhow::anyhow!("selective storage requires a blob dir"))?;
                Some(BlobStore::open(&dir.join(format!("shard{i}")), dim)?)
            } else {
                None
            };
            let mut shard = EdgeIndex::build(
                kind,
                set,
                source.clone(),
                blob,
                scorer.clone(),
                memory.clone(),
                device.clone(),
                &per_shard,
                store_limit,
                slo,
            )?;
            shard.set_region_base((i as u32) << 24);
            built.push(RwLock::new(shard));
        }

        // Pool sizing: the calling thread always walks one shard-group
        // itself, so at most `k − 1` walks per query run remotely; more
        // workers than cores just adds scheduler churn.
        let workers = k
            .saturating_sub(1)
            .min(crate::config::default_shards());
        Ok(ShardedEdgeIndex {
            kind,
            shards: Arc::new(built),
            counters: (0..k).map(|_| ShardCounters::default()).collect(),
            nprobe: retrieval.nprobe,
            device,
            pool: ShardPool::new(workers),
        })
    }

    /// Number of shards.
    pub fn shards(&self) -> usize {
        self.shards.len()
    }

    /// Owning shard of a global cluster id.
    pub fn shard_of(&self, global_cluster: u32) -> usize {
        global_cluster as usize % self.shards.len()
    }

    /// Run `f` against one shard under its read lease (introspection and
    /// tests; holding the guard blocks only that shard's writers).
    pub fn with_shard<R>(&self, shard: usize, f: impl FnOnce(&EdgeIndex) -> R) -> R {
        f(&self.shards[shard].read().unwrap())
    }

    /// Override the probe width (harness sweeps).
    pub fn set_nprobe(&mut self, nprobe: usize) {
        self.nprobe = nprobe;
    }

    /// Pin every shard's caching threshold and disable adaptation (the
    /// Fig. 7 sweep, applied uniformly).
    pub fn pin_threshold(&self, threshold_ms: f64) {
        for shard in self.shards.iter() {
            shard.write().unwrap().pin_threshold(threshold_ms);
        }
    }

    /// Aggregate cache statistics across shards (None when this
    /// configuration has no cache).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        if !self.kind.uses_cache() {
            return None;
        }
        let mut total = CacheStats::default();
        for shard in self.shards.iter() {
            if let Some(s) = shard.read().unwrap().cache_stats() {
                total.hits += s.hits;
                total.misses += s.misses;
                total.insertions += s.insertions;
                total.evictions += s.evictions;
                total.rejected_below_threshold += s.rejected_below_threshold;
            }
        }
        Some(total)
    }

    /// Total bytes resident across all shard caches.
    pub fn cache_used_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().cache_used_bytes())
            .sum()
    }

    /// Global ids of every cluster currently resident in any shard's
    /// cache, sorted (equivalence tests, stats).
    pub fn cached_clusters(&self) -> Vec<u32> {
        let k = self.shards.len() as u32;
        let mut all = Vec::new();
        for (s, shard) in self.shards.iter().enumerate() {
            for local in shard.read().unwrap().cached_clusters() {
                all.push(local * k + s as u32);
            }
        }
        all.sort_unstable();
        all
    }

    /// Total clusters persisted across all shard blob stores.
    pub fn stored_clusters(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().stored_clusters())
            .sum()
    }

    /// Total bytes persisted across all shard blob stores.
    pub fn stored_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().stored_bytes())
            .sum()
    }

    /// Mean adaptive threshold across shards (each shard adapts its own;
    /// the scalar is for dashboards — see [`ShardedEdgeIndex::shard_stats`]
    /// for the per-shard values).
    pub fn threshold_ms(&self) -> f64 {
        let sum: f64 = self
            .shards
            .iter()
            .map(|s| s.read().unwrap().threshold_ms())
            .sum();
        sum / self.shards.len() as f64
    }

    /// Active (non-tombstone) clusters across all shards.
    pub fn active_clusters(&self) -> usize {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().active_clusters())
            .sum()
    }

    /// Global cluster currently holding `chunk`, if any.
    pub fn cluster_of(&self, chunk: u32) -> Option<u32> {
        let k = self.shards.len() as u32;
        for (s, shard) in self.shards.iter().enumerate() {
            if let Some(local) = shard.read().unwrap().cluster_of(chunk) {
                return Some(local * k + s as u32);
            }
        }
        None
    }

    /// Per-shard serving statistics.
    pub fn shard_stats(&self) -> Vec<ShardStats> {
        self.shards
            .iter()
            .enumerate()
            .map(|(i, shard)| {
                let guard = shard.read().unwrap();
                let c = &self.counters[i];
                ShardStats {
                    shard: i,
                    clusters: guard.active_clusters(),
                    probes: c.probes.load(Ordering::Relaxed),
                    cache_hits: c.cache_hits.load(Ordering::Relaxed),
                    generated: c.generated.load(Ordering::Relaxed),
                    loaded: c.loaded.load(Ordering::Relaxed),
                    inserts: c.inserts.load(Ordering::Relaxed),
                    removes: c.removes.load(Ordering::Relaxed),
                    threshold_ms: guard.threshold_ms(),
                    cache_used_bytes: guard.cache_used_bytes(),
                }
            })
            .collect()
    }

    /// The shard an insertion of `emb` would route to (nearest active
    /// centroid across all shards).
    pub fn route(&self, emb: &[f32]) -> Result<usize> {
        let mut best: Option<(usize, f32)> = None;
        for (s, shard) in self.shards.iter().enumerate() {
            let guard = shard.read().unwrap();
            if let Some(&(_, score)) = guard.probe(emb, 1)?.first() {
                // NEG_INFINITY marks a shard whose clusters are all
                // tombstones — never a routing target.
                let better = match best {
                    None => true,
                    Some((_, b)) => score > b,
                };
                if score.is_finite() && better {
                    best = Some((s, score));
                }
            }
        }
        best.map(|(s, _)| s)
            .ok_or_else(|| anyhow::anyhow!("no active clusters"))
    }

    /// Insert a chunk (§5.4), write-leasing **only the owning shard**:
    /// queries to other shards proceed concurrently. `id` must be
    /// globally fresh (the serving engine allocates ids from its shared
    /// text store; duplicate detection here is per-shard only). Returns
    /// the global cluster id the chunk joined.
    pub fn insert_chunk(&self, id: u32, text: &str, emb: &[f32]) -> Result<u32> {
        let target = self.route(emb)?;
        // Routing released its read locks before this write acquire; the
        // shard re-probes internally under the write lease, so a racing
        // merge/split inside the shard cannot misroute the chunk.
        let local = self.shards[target].write().unwrap().insert_chunk(id, text, emb)?;
        self.counters[target].inserts.fetch_add(1, Ordering::Relaxed);
        Ok(local * self.shards.len() as u32 + target as u32)
    }

    /// Remove a chunk (§5.4), write-leasing only the shard that owns it.
    /// Returns false if the chunk is unknown.
    pub fn remove_chunk(&self, id: u32) -> Result<bool> {
        // Chunks never migrate across shards (merges and splits are
        // intra-shard), so the owner found here is stable.
        let owner = (0..self.shards.len())
            .find(|&s| self.shards[s].read().unwrap().cluster_of(id).is_some());
        let Some(s) = owner else { return Ok(false) };
        let removed = self.shards[s].write().unwrap().remove_chunk(id)?;
        if removed {
            self.counters[s].removes.fetch_add(1, Ordering::Relaxed);
        }
        Ok(removed)
    }

    /// Search then immediately commit every shard intent — the
    /// single-caller convenience path (tests, tools), mirroring
    /// [`EdgeIndex::search_and_commit`].
    pub fn search_and_commit(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let out = self.search(query, k)?;
        self.commit(&out.intents, out.ledger.retrieval());
        Ok(out)
    }

    /// Execute the per-shard cluster walks, fanning all but the first
    /// group out to the pool. Returns `(shard, walk)` pairs in arbitrary
    /// order.
    fn run_walks(
        &self,
        query: &[f32],
        work: Vec<(usize, Vec<(u32, u32)>)>,
        k: usize,
    ) -> Result<Vec<(usize, ClusterWalk)>> {
        let mut walks = Vec::with_capacity(work.len());
        if work.len() <= 1 || self.pool.workers == 0 {
            for (s, group) in work {
                let walk = self.shards[s].read().unwrap().search_clusters(query, &group, k)?;
                walks.push((s, walk));
            }
            return Ok(walks);
        }

        let query: Arc<Vec<f32>> = Arc::new(query.to_vec());
        let (tx, rx) = mpsc::channel::<Result<(usize, ClusterWalk)>>();
        let mut iter = work.into_iter();
        let first = iter.next().expect("work checked non-empty");
        let mut remote = 0usize;
        for (s, group) in iter {
            let shards = self.shards.clone();
            let q = query.clone();
            let tx = tx.clone();
            let job: Job = Box::new(move || {
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    shards[s].read().unwrap().search_clusters(&q, &group, k)
                }));
                let msg = match res {
                    Ok(r) => r.map(|walk| (s, walk)),
                    Err(_) => Err(anyhow::anyhow!("shard {s} cluster walk panicked")),
                };
                let _ = tx.send(msg);
            });
            // A refused job (no workers / pool gone) runs on this thread;
            // its result still arrives through the channel.
            if let Err(job) = self.pool.submit(job) {
                job();
            }
            remote += 1;
        }
        drop(tx);

        // Walk the first group on the calling thread while workers run
        // theirs, then collect.
        let (s, group) = first;
        let walk = self.shards[s].read().unwrap().search_clusters(&query, &group, k)?;
        walks.push((s, walk));
        for _ in 0..remote {
            let pair = rx
                .recv()
                .map_err(|_| anyhow::anyhow!("shard pool disconnected"))??;
            walks.push(pair);
        }
        Ok(walks)
    }
}

impl VectorIndex for ShardedEdgeIndex {
    fn kind(&self) -> IndexKind {
        self.kind
    }

    fn search(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let n_shards = self.shards.len();
        let mut ledger = LatencyLedger::new();

        // (1) centroid probe: per-shard masked scores, spliced back into
        // global cluster order so probe selection (and its tie-breaks)
        // matches the unsharded index exactly. One modeled charge for the
        // whole (distributed but byte-identical) centroid table.
        let mut shard_scores = Vec::with_capacity(n_shards);
        let mut centroid_bytes = 0u64;
        let mut width = 0usize;
        for shard in self.shards.iter() {
            let guard = shard.read().unwrap();
            centroid_bytes += guard.clusters().centroid_bytes();
            let scores = guard.probe_scores(query)?;
            width = width.max(scores.len());
            shard_scores.push(scores);
        }
        ledger.charge(
            Component::CentroidProbe,
            self.device.mem_scan_cost(centroid_bytes),
        );
        // Dense (id, score) table over *real* clusters only, in ascending
        // global-id order (`l × n_shards + s` interleaves exactly like the
        // unsharded index's cluster order), so `top_k`'s lower-index tie
        // preference reproduces the unsharded probe — and slots for
        // shards shorter than `width` can never be selected.
        let mut ids: Vec<u32> = Vec::new();
        let mut scores: Vec<f32> = Vec::new();
        for l in 0..width {
            for (s, shard_sc) in shard_scores.iter().enumerate() {
                if let Some(&sc) = shard_sc.get(l) {
                    ids.push((l * n_shards + s) as u32);
                    scores.push(sc);
                }
            }
        }
        let probes = vecmath::top_k(&scores, scores.len(), self.nprobe);

        // Group the probe list by owning shard, preserving each shard's
        // subsequence of the global probe order.
        let mut probed = Vec::with_capacity(probes.len());
        let mut groups: Vec<Vec<(u32, u32)>> = vec![Vec::new(); n_shards];
        for (pos, &(i, _)) in probes.iter().enumerate() {
            let g = ids[i] as usize;
            probed.push(g as u32);
            groups[g % n_shards].push((pos as u32, (g / n_shards) as u32));
        }
        let work: Vec<(usize, Vec<(u32, u32)>)> = groups
            .into_iter()
            .enumerate()
            .filter(|(_, g)| !g.is_empty())
            .collect();
        for (s, group) in &work {
            self.counters[*s]
                .probes
                .fetch_add(group.len() as u64, Ordering::Relaxed);
        }

        // (2..6) fan the cluster walks out and merge.
        let mut walks = self.run_walks(query, work, k)?;
        walks.sort_by_key(|&(s, _)| s); // deterministic intent order

        let mut events = SearchEvents::default();
        let mut intents = Vec::with_capacity(walks.len());
        let mut all_groups: Vec<ClusterHits> = Vec::new();
        for (s, mut walk) in walks {
            ledger.merge(&walk.ledger);
            events.generated += walk.events.generated;
            events.loaded += walk.events.loaded;
            events.cache_hits += walk.events.cache_hits;
            events.thrash_faults += walk.events.thrash_faults;
            let c = &self.counters[s];
            c.cache_hits
                .fetch_add(walk.events.cache_hits as u64, Ordering::Relaxed);
            c.generated
                .fetch_add(walk.events.generated as u64, Ordering::Relaxed);
            c.loaded
                .fetch_add(walk.events.loaded as u64, Ordering::Relaxed);
            walk.intent.shard = s;
            intents.push(walk.intent);
            all_groups.append(&mut walk.groups);
        }

        // Merge the per-shard heaps: candidates re-sorted into global
        // probe order make the final top-k (ties included) identical to a
        // sequential walk's.
        all_groups.sort_by_key(|g| g.probe_pos);
        let all_hits: Vec<(u32, f32)> = all_groups.into_iter().flat_map(|g| g.hits).collect();
        let scores: Vec<f32> = all_hits.iter().map(|&(_, s)| s).collect();
        let top = vecmath::top_k(&scores, all_hits.len(), k);
        let hits = top.into_iter().map(|(i, s)| (all_hits[i].0, s)).collect();

        Ok(SearchOutcome {
            hits,
            ledger,
            probed,
            events,
            intents,
        })
    }

    /// Commit each shard's intent independently: only that shard's
    /// controller/cache locks are taken, so commits for different shards
    /// (from this or other queries) never serialize on each other.
    fn commit(&self, intents: &[CacheIntent], retrieval: SimDuration) {
        for intent in intents {
            let Some(shard) = self.shards.get(intent.shard) else {
                continue;
            };
            shard.read().unwrap().commit_intent(intent, retrieval);
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> u64 {
        self.shards
            .iter()
            .map(|s| s.read().unwrap().resident_bytes())
            .sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetProfile;
    use crate::data::Corpus;
    use crate::embedding::{Embedder, EmbedderBackend};
    use crate::index::kmeans::{kmeans, KMeansConfig};
    use crate::index::shared_memory;
    use crate::testutil::shared_compute;

    struct Fixture {
        corpus: Corpus,
        emb: Arc<EmbeddingMatrix>,
        device: DeviceProfile,
        scorer: Scorer,
        embedder: Embedder,
    }

    fn fixture() -> Fixture {
        let profile = DatasetProfile::tiny();
        let corpus = Corpus::generate(&profile);
        let compute = shared_compute();
        let embedder = Embedder::new(compute.clone(), EmbedderBackend::Projection);
        let emb = Arc::new(embedder.embed_texts(&corpus.texts()).unwrap());
        Fixture {
            corpus,
            emb,
            device: DeviceProfile::jetson_orin_nano(),
            scorer: Scorer::new(compute),
            embedder,
        }
    }

    fn cluster_set(f: &Fixture) -> ClusterSet {
        let km = kmeans(
            &f.emb,
            &KMeansConfig {
                n_clusters: 8,
                iterations: 5,
                seed: 1,
                init: None,
            },
            &f.scorer,
        )
        .unwrap();
        ClusterSet::build(&f.corpus, km.centroids, &km.assignment, &f.device)
    }

    fn state_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("edgerag-shard-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn retrieval() -> RetrievalConfig {
        RetrievalConfig {
            nprobe: 4,
            ..Default::default()
        }
    }

    fn build_sharded(f: &Fixture, tag: &str, shards: usize) -> ShardedEdgeIndex {
        let dir = state_dir(tag);
        ShardedEdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(dir.as_path()),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &retrieval(),
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
            shards,
        )
        .unwrap()
    }

    fn build_edge(f: &Fixture, tag: &str) -> EdgeIndex {
        let dir = state_dir(tag);
        let blob = BlobStore::open(&dir, f.scorer.dim()).unwrap();
        EdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(blob),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &retrieval(),
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
        )
        .unwrap()
    }

    #[test]
    fn partition_covers_every_cluster() {
        let f = fixture();
        let set = cluster_set(&f);
        let total = set.n_clusters();
        let idx = build_sharded(&f, "part", 3);
        assert_eq!(idx.shards(), 3);
        let per_shard: usize = (0..3).map(|s| idx.with_shard(s, |e| e.clusters().n_clusters())).sum();
        assert_eq!(per_shard, total);
        // Every chunk is still owned by exactly one (global) cluster.
        for chunk in [0u32, 17, 101, 300] {
            let g = idx.cluster_of(chunk).expect("chunk routed");
            assert_eq!(idx.shard_of(g), g as usize % 3);
        }
    }

    #[test]
    fn single_shard_is_bit_identical_to_edge_index() {
        let f = fixture();
        let edge = build_edge(&f, "bit-e");
        let sharded = build_sharded(&f, "bit-s", 1);
        for i in [0usize, 17, 101, 300, 443] {
            let q = f.emb.row(i).to_vec();
            let a = edge.search(&q, 5).unwrap();
            let b = sharded.search(&q, 5).unwrap();
            assert_eq!(a.hits, b.hits, "query {i}");
            assert_eq!(a.probed, b.probed, "query {i}");
            assert_eq!(a.ledger.total(), b.ledger.total(), "query {i}");
            assert_eq!(a.events.generated, b.events.generated, "query {i}");
            assert_eq!(a.events.loaded, b.events.loaded, "query {i}");
            assert_eq!(b.intents.len(), 1);
            assert_eq!(b.intents[0].shard, 0);
        }
    }

    #[test]
    fn four_shards_identical_topk_and_admissions() {
        // The satellite equivalence property at unit scale: same corpus,
        // same queries → identical top-k and identical per-cluster cache
        // admissions for shards=1 vs shards=4 (thresholds pinned so the
        // per-shard feedback loops cannot diverge).
        let f = fixture();
        let one = build_sharded(&f, "eq1", 1);
        let four = build_sharded(&f, "eq4", 4);
        one.pin_threshold(0.0);
        four.pin_threshold(0.0);
        for i in 0..16usize {
            let q = f.emb.row(i * 30).to_vec();
            let a = one.search_and_commit(&q, 5).unwrap();
            let b = four.search_and_commit(&q, 5).unwrap();
            assert_eq!(a.hits, b.hits, "query {i}");
            assert_eq!(a.events.generated, b.events.generated, "query {i}");
            assert_eq!(a.events.cache_hits, b.events.cache_hits, "query {i}");
        }
        assert_eq!(one.cached_clusters(), four.cached_clusters());
    }

    #[test]
    fn insert_and_remove_route_to_owning_shard() {
        let f = fixture();
        let idx = build_sharded(&f, "ins", 4);
        let text = "a fresh shard-routed document with marker tokens zzshard yyshard";
        let emb = f.embedder.embed_one(text).unwrap();
        let id = f.corpus.len() as u32 + 7;
        let expected_shard = idx.route(&emb).unwrap();
        let cluster = idx.insert_chunk(id, text, &emb).unwrap();
        assert_eq!(idx.shard_of(cluster), expected_shard);
        assert_eq!(idx.cluster_of(id), Some(cluster));
        let out = idx.search_and_commit(&emb, 3).unwrap();
        assert_eq!(out.hits[0].0, id, "hits: {:?}", out.hits);
        let stats = idx.shard_stats();
        assert_eq!(stats[expected_shard].inserts, 1);
        assert!(idx.remove_chunk(id).unwrap());
        assert_eq!(idx.cluster_of(id), None);
        assert!(!idx.remove_chunk(id).unwrap(), "second remove is a no-op");
    }

    #[test]
    fn insert_does_not_block_readers_of_other_shards() {
        // The tentpole overlap property, made deterministic: hold a read
        // lease on a shard the insert does NOT own; the insert must still
        // complete.
        let f = fixture();
        let idx = Arc::new(build_sharded(&f, "overlap", 4));
        let text = "overlap probe document zzoverlap";
        let emb = f.embedder.embed_one(text).unwrap();
        let target = idx.route(&emb).unwrap();
        let other = (target + 1) % idx.shards();
        let id = f.corpus.len() as u32 + 11;
        idx.with_shard(other, |_held| {
            let (tx, rx) = mpsc::channel();
            let idx2 = idx.clone();
            let emb2 = emb.clone();
            let text2 = text.to_string();
            std::thread::spawn(move || {
                let _ = tx.send(idx2.insert_chunk(id, &text2, &emb2).map(|_| ()));
            });
            rx.recv_timeout(std::time::Duration::from_secs(10))
                .expect("insert must not block on an unrelated shard's read lease")
                .expect("insert succeeds");
        });
        assert_eq!(idx.cluster_of(id).map(|g| idx.shard_of(g)), Some(target));
    }

    #[test]
    fn concurrent_queries_and_inserts_stay_consistent() {
        let f = fixture();
        let idx = build_sharded(&f, "conc", 4);
        let queries: Vec<Vec<f32>> = (0..8).map(|i| f.emb.row(i * 50).to_vec()).collect();
        let serial: Vec<Vec<u32>> = queries
            .iter()
            .map(|q| idx.search(q, 5).unwrap().hits.iter().map(|h| h.0).collect())
            .collect();
        let base = f.corpus.len() as u32 + 100;
        std::thread::scope(|scope| {
            for _ in 0..2 {
                let idx = &idx;
                let queries = &queries;
                scope.spawn(move || {
                    for _ in 0..3 {
                        for q in queries {
                            // Concurrent inserts may add hits but must
                            // never corrupt a search.
                            let out = idx.search_and_commit(q, 5).unwrap();
                            assert!(!out.hits.is_empty());
                        }
                    }
                });
            }
            let idx = &idx;
            let embedder = &f.embedder;
            scope.spawn(move || {
                for i in 0..10u32 {
                    let text = format!("concurrent insert number {i} marker zzconc{i}");
                    let emb = embedder.embed_one(&text).unwrap();
                    idx.insert_chunk(base + i, &text, &emb).unwrap();
                }
            });
        });
        // After the dust settles: serial agreement for the original
        // corpus' queries still holds on the top hit (inserted docs can
        // only displace weaker candidates), and every insert is routed.
        for (i, q) in queries.iter().enumerate() {
            let ids: Vec<u32> = idx.search(q, 5).unwrap().hits.iter().map(|h| h.0).collect();
            assert_eq!(ids[0], serial[i][0], "query {i} top hit changed");
        }
        let total_inserts: u64 = idx.shard_stats().iter().map(|s| s.inserts).sum();
        assert_eq!(total_inserts, 10);
        for i in 0..10u32 {
            assert!(idx.cluster_of(base + i).is_some(), "insert {i} lost");
        }
    }

    #[test]
    fn rejects_too_many_shards() {
        let f = fixture();
        let dir = state_dir("max");
        let err = ShardedEdgeIndex::build(
            IndexKind::EdgeRag,
            cluster_set(&f),
            EmbedSource::Prebuilt(f.emb.clone()),
            Some(dir.as_path()),
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &retrieval(),
            SimDuration::from_millis(150),
            SimDuration::from_millis(1_000),
            MAX_SHARDS + 1,
        );
        assert!(err.is_err());
    }
}
