//! Similarity scoring service over the PJRT `sim_*` executables (the
//! Pallas similarity kernel). Both IVF levels, the flat baseline scan and
//! the k-means assignment step all score through here.

use anyhow::Result;

use crate::runtime::{ComputeHandle, Tensor};
use crate::vecmath::{self, EmbeddingMatrix};

/// Similarity scorer bound to one compute executor; cheap to clone
/// (shards and worker threads share the underlying handle).
#[derive(Clone)]
pub struct Scorer {
    compute: ComputeHandle,
    sim_rows: Vec<usize>,
    kmeans_batch: usize,
    kmeans_rows: usize,
    dim: usize,
}

impl Scorer {
    /// Bind to a compute executor, reading kernel shapes from its
    /// manifest.
    pub fn new(compute: ComputeHandle) -> Self {
        let m = compute.manifest();
        Scorer {
            sim_rows: m.sim_rows.clone(),
            kmeans_batch: 32,
            kmeans_rows: 512,
            dim: m.dim,
            compute,
        }
    }

    /// Embedding dimensionality the compiled kernels expect.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Max rows scoreable against in one batched (k-means) call.
    pub fn max_batch_rows(&self) -> usize {
        self.kmeans_rows
    }

    fn bucket_for(&self, rows: usize) -> usize {
        self.sim_rows
            .iter()
            .copied()
            .find(|&b| b >= rows)
            .unwrap_or_else(|| *self.sim_rows.last().unwrap())
    }

    /// Scores of one query against every row (chunking any size through
    /// the compiled buckets; padding rows are sliced away).
    pub fn scores(&self, q: &[f32], rows: &EmbeddingMatrix) -> Result<Vec<f32>> {
        assert_eq!(q.len(), self.dim);
        assert_eq!(rows.dim, self.dim);
        let n = rows.len();
        let max_bucket = *self.sim_rows.last().unwrap();
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        while start < n {
            let take = (n - start).min(max_bucket);
            let bucket = self.bucket_for(take);
            let mut chunk = Vec::with_capacity(bucket * self.dim);
            chunk.extend_from_slice(&rows.data[start * self.dim..(start + take) * self.dim]);
            chunk.resize(bucket * self.dim, 0.0);
            let res = self.compute.run(
                &format!("sim_1x{bucket}"),
                vec![
                    Tensor::F32(q.to_vec(), vec![1, self.dim]),
                    Tensor::F32(chunk, vec![bucket, self.dim]),
                ],
            )?;
            out.extend_from_slice(&res[0][..take]);
            start += take;
        }
        Ok(out)
    }

    /// Top-k (index, score) of one query against rows, descending.
    pub fn top_k(
        &self,
        q: &[f32],
        rows: &EmbeddingMatrix,
        k: usize,
    ) -> Result<Vec<(usize, f32)>> {
        let scores = self.scores(q, rows)?;
        Ok(vecmath::top_k(&scores, rows.len(), k))
    }

    /// Batched scores for the k-means assignment step: up to 32 points ×
    /// up to 512 centroids per call. Returns a row-major (points × n)
    /// score matrix.
    pub fn batch_scores(
        &self,
        points: &EmbeddingMatrix,
        centroids: &EmbeddingMatrix,
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(points.dim, self.dim);
        assert_eq!(centroids.dim, self.dim);
        let n = centroids.len();
        assert!(
            n <= self.kmeans_rows,
            "batch_scores supports ≤{} centroids",
            self.kmeans_rows
        );
        let cent_pad = centroids.padded(self.kmeans_rows);
        let artifact = format!("sim_{}x{}", self.kmeans_batch, self.kmeans_rows);

        let mut out: Vec<Vec<f32>> = Vec::with_capacity(points.len());
        let mut start = 0;
        while start < points.len() {
            let take = (points.len() - start).min(self.kmeans_batch);
            let mut batch = Vec::with_capacity(self.kmeans_batch * self.dim);
            batch.extend_from_slice(&points.data[start * self.dim..(start + take) * self.dim]);
            batch.resize(self.kmeans_batch * self.dim, 0.0);
            let res = self.compute.run(
                &artifact,
                vec![
                    Tensor::F32(batch, vec![self.kmeans_batch, self.dim]),
                    Tensor::F32(cent_pad.clone(), vec![self.kmeans_rows, self.dim]),
                ],
            )?;
            for j in 0..take {
                out.push(res[0][j * self.kmeans_rows..j * self.kmeans_rows + n].to_vec());
            }
            start += take;
        }
        Ok(out)
    }
}
