//! Similarity scoring service over the PJRT `sim_*` executables (the
//! Pallas similarity kernel). Both IVF levels, the flat baseline scan and
//! the k-means assignment step all score through here.

use anyhow::Result;

use crate::runtime::{ComputeHandle, Tensor};
use crate::vecmath::{self, EmbeddingMatrix};

/// Similarity scorer bound to one compute executor; cheap to clone
/// (shards and worker threads share the underlying handle).
#[derive(Clone)]
pub struct Scorer {
    compute: ComputeHandle,
    sim_rows: Vec<usize>,
    /// Query-batch widths of the compiled `sim_{A}x{N}` family, ascending
    /// (`[1]` on manifests predating cross-query batching).
    sim_batches: Vec<usize>,
    kmeans_batch: usize,
    kmeans_rows: usize,
    dim: usize,
}

impl Scorer {
    /// Bind to a compute executor, reading kernel shapes from its
    /// manifest.
    pub fn new(compute: ComputeHandle) -> Self {
        let m = compute.manifest();
        let mut sim_batches = m.sim_batches.clone();
        if sim_batches.is_empty() {
            sim_batches.push(1);
        }
        sim_batches.sort_unstable();
        Scorer {
            sim_rows: m.sim_rows.clone(),
            sim_batches,
            kmeans_batch: 32,
            kmeans_rows: 512,
            dim: m.dim,
            compute,
        }
    }

    /// Embedding dimensionality the compiled kernels expect.
    pub fn dim(&self) -> usize {
        self.dim
    }

    /// Max rows scoreable against in one batched (k-means) call.
    pub fn max_batch_rows(&self) -> usize {
        self.kmeans_rows
    }

    fn bucket_for(&self, rows: usize) -> usize {
        self.sim_rows
            .iter()
            .copied()
            .find(|&b| b >= rows)
            .unwrap_or_else(|| *self.sim_rows.last().unwrap())
    }

    /// Scores of one query against every row (chunking any size through
    /// the compiled buckets; padding rows are sliced away).
    pub fn scores(&self, q: &[f32], rows: &EmbeddingMatrix) -> Result<Vec<f32>> {
        assert_eq!(q.len(), self.dim);
        assert_eq!(rows.dim, self.dim);
        let n = rows.len();
        let max_bucket = *self.sim_rows.last().unwrap();
        let mut out = Vec::with_capacity(n);
        let mut start = 0;
        while start < n {
            let take = (n - start).min(max_bucket);
            let bucket = self.bucket_for(take);
            let mut chunk = Vec::with_capacity(bucket * self.dim);
            chunk.extend_from_slice(&rows.data[start * self.dim..(start + take) * self.dim]);
            chunk.resize(bucket * self.dim, 0.0);
            let res = self.compute.run(
                &format!("sim_1x{bucket}"),
                vec![
                    Tensor::F32(q.to_vec(), vec![1, self.dim]),
                    Tensor::F32(chunk, vec![bucket, self.dim]),
                ],
            )?;
            out.extend_from_slice(&res[0][..take]);
            start += take;
        }
        Ok(out)
    }

    /// Widest compiled query batch of the `sim_{A}x{N}` family — the
    /// natural width of a cross-query probe batch.
    pub fn max_sim_batch(&self) -> usize {
        *self.sim_batches.last().unwrap()
    }

    /// Scores of **several queries** against the same rows in fused
    /// `sim_{A}x{N}` kernel calls — the cross-query batched counterpart
    /// of [`Scorer::scores`]. Queries are chunked into the smallest
    /// compiled query-batch bucket that fits (padding rows are zero and
    /// sliced away); rows are tiled exactly like the single-query path.
    ///
    /// Bit-equivalence: the similarity kernels compute independent
    /// per-(query, row) inner products, so each query's score vector is
    /// identical to what `scores` returns for it alone (verified by
    /// `multi_query_scores_match_single` below).
    pub fn scores_multi(
        &self,
        queries: &[&[f32]],
        rows: &EmbeddingMatrix,
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(rows.dim, self.dim);
        if queries.len() == 1 {
            return Ok(vec![self.scores(queries[0], rows)?]);
        }
        let n = rows.len();
        let max_rows = *self.sim_rows.last().unwrap();
        let mut out: Vec<Vec<f32>> = queries.iter().map(|_| Vec::with_capacity(n)).collect();
        let mut qi = 0;
        while qi < queries.len() {
            let remaining = queries.len() - qi;
            // Smallest compiled query bucket that covers the remainder
            // (largest bucket when the remainder exceeds every bucket).
            let qb = self
                .sim_batches
                .iter()
                .copied()
                .find(|&b| b >= remaining)
                .unwrap_or_else(|| *self.sim_batches.last().unwrap());
            let take_q = qb.min(remaining);
            let mut qbuf = Vec::with_capacity(qb * self.dim);
            for q in &queries[qi..qi + take_q] {
                assert_eq!(q.len(), self.dim);
                qbuf.extend_from_slice(q);
            }
            qbuf.resize(qb * self.dim, 0.0);

            let mut start = 0;
            while start < n {
                let take = (n - start).min(max_rows);
                let bucket = self.bucket_for(take);
                let mut chunk = Vec::with_capacity(bucket * self.dim);
                chunk.extend_from_slice(
                    &rows.data[start * self.dim..(start + take) * self.dim],
                );
                chunk.resize(bucket * self.dim, 0.0);
                let res = self.compute.run(
                    &format!("sim_{qb}x{bucket}"),
                    vec![
                        Tensor::F32(qbuf.clone(), vec![qb, self.dim]),
                        Tensor::F32(chunk, vec![bucket, self.dim]),
                    ],
                )?;
                for (j, o) in out[qi..qi + take_q].iter_mut().enumerate() {
                    o.extend_from_slice(&res[0][j * bucket..j * bucket + take]);
                }
                start += take;
            }
            qi += take_q;
        }
        Ok(out)
    }

    /// Top-k (index, score) of one query against rows, descending.
    pub fn top_k(
        &self,
        q: &[f32],
        rows: &EmbeddingMatrix,
        k: usize,
    ) -> Result<Vec<(usize, f32)>> {
        let scores = self.scores(q, rows)?;
        Ok(vecmath::top_k(&scores, rows.len(), k))
    }

    /// Batched scores for the k-means assignment step: up to 32 points ×
    /// up to 512 centroids per call. Returns a row-major (points × n)
    /// score matrix.
    pub fn batch_scores(
        &self,
        points: &EmbeddingMatrix,
        centroids: &EmbeddingMatrix,
    ) -> Result<Vec<Vec<f32>>> {
        assert_eq!(points.dim, self.dim);
        assert_eq!(centroids.dim, self.dim);
        let n = centroids.len();
        assert!(
            n <= self.kmeans_rows,
            "batch_scores supports ≤{} centroids",
            self.kmeans_rows
        );
        let cent_pad = centroids.padded(self.kmeans_rows);
        let artifact = format!("sim_{}x{}", self.kmeans_batch, self.kmeans_rows);

        let mut out: Vec<Vec<f32>> = Vec::with_capacity(points.len());
        let mut start = 0;
        while start < points.len() {
            let take = (points.len() - start).min(self.kmeans_batch);
            let mut batch = Vec::with_capacity(self.kmeans_batch * self.dim);
            batch.extend_from_slice(&points.data[start * self.dim..(start + take) * self.dim]);
            batch.resize(self.kmeans_batch * self.dim, 0.0);
            let res = self.compute.run(
                &artifact,
                vec![
                    Tensor::F32(batch, vec![self.kmeans_batch, self.dim]),
                    Tensor::F32(cent_pad.clone(), vec![self.kmeans_rows, self.dim]),
                ],
            )?;
            for j in 0..take {
                out.push(res[0][j * self.kmeans_rows..j * self.kmeans_rows + n].to_vec());
            }
            start += take;
        }
        Ok(out)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::testutil::shared_compute;

    fn random_matrix(rng: &mut Rng, dim: usize, rows: usize) -> EmbeddingMatrix {
        let mut m = EmbeddingMatrix::with_capacity(dim, rows);
        for _ in 0..rows {
            let row: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            m.push(&row);
        }
        m
    }

    #[test]
    fn multi_query_scores_match_single() {
        // The cross-query batched entry must be bit-identical to the
        // per-query path for every query — the foundation of the batch
        // scheduler's equivalence guarantee.
        let scorer = Scorer::new(shared_compute());
        let dim = scorer.dim();
        let mut rng = Rng::new(42);
        // 300 rows spans multiple row tiles at the 128/256 buckets; 11
        // queries spans the 1/8/32 query buckets with padding.
        let rows = random_matrix(&mut rng, dim, 300);
        let queries = random_matrix(&mut rng, dim, 11);
        let refs: Vec<&[f32]> = queries.iter_rows().collect();
        let batched = scorer.scores_multi(&refs, &rows).unwrap();
        assert_eq!(batched.len(), refs.len());
        for (i, q) in refs.iter().enumerate() {
            let single = scorer.scores(q, &rows).unwrap();
            assert_eq!(batched[i], single, "query {i} diverged");
        }
    }

    #[test]
    fn multi_query_handles_edge_sizes() {
        let scorer = Scorer::new(shared_compute());
        let dim = scorer.dim();
        let mut rng = Rng::new(7);
        let rows = random_matrix(&mut rng, dim, 3);
        let q = random_matrix(&mut rng, dim, 1);
        let refs: Vec<&[f32]> = q.iter_rows().collect();
        let one = scorer.scores_multi(&refs, &rows).unwrap();
        assert_eq!(one.len(), 1);
        assert_eq!(one[0], scorer.scores(refs[0], &rows).unwrap());
        let none: Vec<&[f32]> = Vec::new();
        assert!(scorer.scores_multi(&none, &rows).unwrap().is_empty());
    }
}
