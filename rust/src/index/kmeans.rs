//! K-means clustering for the IVF first level.
//!
//! The paper uses FAISS K-means (20 iterations, §6.2); this is the same
//! algorithm — k-means++ seeding + Lloyd iterations — with the assignment
//! step running through the PJRT similarity kernel (`Scorer::batch_scores`)
//! and maximizing cosine similarity over unit vectors (equivalent to
//! minimizing Euclidean distance on the unit sphere). Empty clusters are
//! reseeded from the largest cluster's farthest members.

use anyhow::Result;

use crate::data::Rng;
use crate::index::Scorer;
use crate::vecmath::{self, EmbeddingMatrix};

/// Clustering parameters (defaults mirror the paper's FAISS setup).
#[derive(Debug, Clone, Default)]
pub struct KMeansConfig {
    /// First-level size (clusters to produce).
    pub n_clusters: usize,
    /// Lloyd iterations after seeding.
    pub iterations: usize,
    /// Deterministic seeding RNG.
    pub seed: u64,
    /// Optional warm-start centroids (e.g. topic means for large corpora —
    /// see `SystemBuilder::build_dataset`). Must have `n_clusters` rows;
    /// skips k-means++ seeding.
    pub init: Option<EmbeddingMatrix>,
}

impl KMeansConfig {
    /// Paper defaults (20 iterations, fixed seed) for `n_clusters`.
    pub fn new(n_clusters: usize) -> Self {
        KMeansConfig {
            n_clusters,
            iterations: 20, // paper §6.2
            seed: 42,
            init: None,
        }
    }
}

#[derive(Debug)]
pub struct KMeansResult {
    /// Unit-normalized centroids (n_clusters × dim).
    pub centroids: EmbeddingMatrix,
    /// Cluster id per input point.
    pub assignment: Vec<u32>,
}

/// Run k-means over unit-vector `points`.
pub fn kmeans(points: &EmbeddingMatrix, cfg: &KMeansConfig, scorer: &Scorer) -> Result<KMeansResult> {
    let n = points.len();
    let dim = points.dim;
    let k = cfg.n_clusters.min(n).max(1);
    let mut rng = Rng::new(cfg.seed);

    let mut centroids = match &cfg.init {
        Some(init) => {
            assert_eq!(init.len(), k, "init must have n_clusters rows");
            assert_eq!(init.dim, dim);
            init.clone()
        }
        None => init_plus_plus(points, k, &mut rng),
    };
    let mut assignment = vec![0u32; n];

    for _iter in 0..cfg.iterations {
        // Assignment: argmax cosine via the PJRT kernel, chunking the
        // centroid set through the batched scorer's row limit.
        assign(points, &centroids, scorer, &mut assignment)?;

        // Update: mean of members, re-normalized to the unit sphere.
        let mut sums = vec![0.0f64; k * dim];
        let mut counts = vec![0usize; k];
        for (i, &a) in assignment.iter().enumerate() {
            counts[a as usize] += 1;
            let row = points.row(i);
            let s = &mut sums[a as usize * dim..(a as usize + 1) * dim];
            for (acc, v) in s.iter_mut().zip(row) {
                *acc += *v as f64;
            }
        }
        // Reseed empties from random points of the largest cluster.
        let largest = (0..k).max_by_key(|&c| counts[c]).unwrap();
        let mut new_centroids = EmbeddingMatrix::with_capacity(dim, k);
        for c in 0..k {
            if counts[c] == 0 {
                let members: Vec<usize> = assignment
                    .iter()
                    .enumerate()
                    .filter(|(_, &a)| a as usize == largest)
                    .map(|(i, _)| i)
                    .collect();
                let pick = members[rng.below(members.len())];
                new_centroids.push(points.row(pick));
                continue;
            }
            let mut row: Vec<f32> = sums[c * dim..(c + 1) * dim]
                .iter()
                .map(|&v| (v / counts[c] as f64) as f32)
                .collect();
            let norm = vecmath::l2_norm(&row).max(1e-9);
            for v in &mut row {
                *v /= norm;
            }
            new_centroids.push(&row);
        }
        centroids = new_centroids;
    }
    assign(points, &centroids, scorer, &mut assignment)?;

    Ok(KMeansResult {
        centroids,
        assignment,
    })
}

fn assign(
    points: &EmbeddingMatrix,
    centroids: &EmbeddingMatrix,
    scorer: &Scorer,
    assignment: &mut [u32],
) -> Result<()> {
    let k = centroids.len();
    let limit = scorer.max_batch_rows();
    let mut best = vec![f32::NEG_INFINITY; points.len()];
    let mut start = 0;
    while start < k {
        let take = (k - start).min(limit);
        let mut sub = EmbeddingMatrix::with_capacity(centroids.dim, take);
        for c in start..start + take {
            sub.push(centroids.row(c));
        }
        let scores = scorer.batch_scores(points, &sub)?;
        for (i, row) in scores.iter().enumerate() {
            let local = vecmath::argmax(row);
            if row[local] > best[i] {
                best[i] = row[local];
                assignment[i] = (start + local) as u32;
            }
        }
        start += take;
    }
    Ok(())
}

/// k-means++ seeding: first centroid uniform, the rest proportional to
/// (1 - max cosine similarity to the chosen set) — the spherical analogue
/// of squared distance.
fn init_plus_plus(points: &EmbeddingMatrix, k: usize, rng: &mut Rng) -> EmbeddingMatrix {
    let n = points.len();
    let dim = points.dim;
    let mut centroids = EmbeddingMatrix::with_capacity(dim, k);
    let first = rng.below(n);
    centroids.push(points.row(first));
    let mut best_sim = vec![f32::NEG_INFINITY; n];

    while centroids.len() < k {
        let newest = centroids.row(centroids.len() - 1);
        let mut weights = Vec::with_capacity(n);
        let mut total = 0.0f64;
        for i in 0..n {
            let s = vecmath::dot(points.row(i), newest);
            if s > best_sim[i] {
                best_sim[i] = s;
            }
            let w = ((1.0 - best_sim[i]) as f64).max(0.0);
            let w = w * w;
            weights.push(w);
            total += w;
        }
        let pick = if total <= 0.0 {
            rng.below(n)
        } else {
            let mut target = rng.f64() * total;
            let mut idx = n - 1;
            for (i, w) in weights.iter().enumerate() {
                if target < *w {
                    idx = i;
                    break;
                }
                target -= w;
            }
            idx
        };
        centroids.push(points.row(pick));
    }
    centroids
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::testutil::shared_compute;

    /// Three well-separated synthetic direction groups.
    fn grouped_points(dim: usize, per_group: usize) -> (EmbeddingMatrix, Vec<u32>) {
        let mut rng = Rng::new(9);
        let mut m = EmbeddingMatrix::new(dim);
        let mut truth = Vec::new();
        for g in 0..3u32 {
            // group direction: one-hot-ish base + small noise
            for _ in 0..per_group {
                let mut row = vec![0.0f32; dim];
                row[g as usize * 3] = 1.0;
                for v in row.iter_mut() {
                    *v += 0.05 * rng.normal() as f32;
                }
                let norm = vecmath::l2_norm(&row);
                for v in row.iter_mut() {
                    *v /= norm;
                }
                m.push(&row);
                truth.push(g);
            }
        }
        (m, truth)
    }

    #[test]
    fn recovers_separated_groups() {
        let scorer = Scorer::new(shared_compute());
        let (points, truth) = grouped_points(scorer.dim(), 40);
        let res = kmeans(
            &points,
            &KMeansConfig {
                n_clusters: 3,
                iterations: 10,
                seed: 1,
                init: None,
            },
            &scorer,
        )
        .unwrap();
        // Every ground-truth group must map to exactly one k-means cluster.
        for g in 0..3u32 {
            let ids: std::collections::HashSet<u32> = truth
                .iter()
                .enumerate()
                .filter(|(_, &t)| t == g)
                .map(|(i, _)| res.assignment[i])
                .collect();
            assert_eq!(ids.len(), 1, "group {g} split across clusters");
        }
    }

    #[test]
    fn centroids_are_unit_norm() {
        let scorer = Scorer::new(shared_compute());
        let (points, _) = grouped_points(scorer.dim(), 20);
        let res = kmeans(&points, &KMeansConfig::new(16), &scorer).unwrap();
        for i in 0..res.centroids.len() {
            let n = vecmath::l2_norm(res.centroids.row(i));
            assert!((n - 1.0).abs() < 1e-3, "centroid {i} norm {n}");
        }
    }

    #[test]
    fn deterministic_given_seed() {
        let scorer = Scorer::new(shared_compute());
        let (points, _) = grouped_points(scorer.dim(), 15);
        let cfg = KMeansConfig {
            n_clusters: 4,
            iterations: 5,
            seed: 7,
                init: None,
            };
        let a = kmeans(&points, &cfg, &scorer).unwrap();
        let b = kmeans(&points, &cfg, &scorer).unwrap();
        assert_eq!(a.assignment, b.assignment);
    }

    #[test]
    fn k_larger_than_n_clamps() {
        let scorer = Scorer::new(shared_compute());
        let (points, _) = grouped_points(scorer.dim(), 2); // n=6
        let res = kmeans(
            &points,
            &KMeansConfig {
                n_clusters: 50,
                iterations: 3,
                seed: 3,
                init: None,
            },
            &scorer,
        )
        .unwrap();
        assert_eq!(res.centroids.len(), 6);
        assert!(res.assignment.iter().all(|&a| a < 6));
    }

    #[test]
    fn assignment_is_nearest_centroid() {
        let scorer = Scorer::new(shared_compute());
        let (points, _) = grouped_points(scorer.dim(), 20);
        let res = kmeans(
            &points,
            &KMeansConfig {
                n_clusters: 3,
                iterations: 8,
                seed: 2,
                init: None,
            },
            &scorer,
        )
        .unwrap();
        for i in (0..points.len()).step_by(7) {
            let sims: Vec<f32> = (0..res.centroids.len())
                .map(|c| vecmath::dot(points.row(i), res.centroids.row(c)))
                .collect();
            assert_eq!(
                vecmath::argmax(&sims) as u32,
                res.assignment[i],
                "point {i} not assigned to nearest centroid"
            );
        }
    }
}
