//! The EdgeRAG index (paper §5, Table 4 rows "IVF+Gen", "IVF+Gen+Load",
//! "EdgeRAG").
//!
//! Second-level embeddings are pruned from memory. On a probe, embeddings
//! come from (in priority order, mirroring Fig. 9):
//!
//! 1. the **blob store** — clusters whose profiled generation cost exceeds
//!    the SLO-derived limit were precomputed at indexing time (§4.1,
//!    Algorithm 1) and load as contiguous blobs;
//! 2. the **cost-aware cache** (EdgeRAG only) — previously generated
//!    embeddings, kept under Algorithm 2's `genLatency × counter` policy,
//!    gated by Algorithm 3's adaptive threshold;
//! 3. **online generation** — the embedding model re-embeds the cluster's
//!    chunks (charged at the device's generation rate; numerics through
//!    the real PJRT embedder or the verified-equal prebuilt matrix).
//!
//! ## Concurrency
//!
//! `search` takes `&self` and is safe to call from many threads at once:
//! the cost-aware cache sits behind an `RwLock` probed with read locks
//! ([`CostAwareCache::peek`]), the adaptive threshold behind its own
//! `RwLock`, and residency accounting behind the shared memory-model
//! mutex. All LFU/threshold *mutations* a search implies are recorded in
//! the outcome's [`CacheIntent`] and applied later by
//! [`VectorIndex::commit`], which takes the write locks briefly. Online
//! inserts/removes still require `&mut self`; a generation counter lets
//! the commit discard admissions that raced a structural update.
//!
//! An `EdgeIndex` also serves as **one shard** of a
//! [`ShardedEdgeIndex`](crate::index::ShardedEdgeIndex): the sharded
//! wrapper probes centroids across shards, then drives each shard's
//! cluster walk through [`EdgeIndex::search_clusters`] — the exact code
//! path a standalone search uses — so sharded and unsharded results are
//! bit-identical. See `docs/ARCHITECTURE.md` for the lock hierarchy.

use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Arc, RwLock};

use anyhow::Result;

use crate::cache::{CacheStats, CostAwareCache, ThresholdController};
use crate::config::{DeviceProfile, IndexKind, RetrievalConfig};
use crate::index::{
    AdmitCandidate, CacheAccess, CacheIntent, ClusterSet, EmbedSource, ProbeTable, Scorer,
    SearchEvents, SearchOutcome, ShardWalk, SharedMemory, VectorIndex,
};
use crate::simtime::{Component, LatencyLedger, SimDuration};
use crate::storage::{BlobStore, Region, WalActivity, WalOp, WriteAheadLog};
use crate::trace;
use crate::vecmath;

/// Which optional stages are enabled (Table 4).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct EdgeFeatures {
    /// Precompute + load heavy tail clusters from storage (§4.1).
    pub selective_storage: bool,
    /// Cost-aware adaptive caching (§4.2).
    pub caching: bool,
}

impl EdgeFeatures {
    pub fn for_kind(kind: IndexKind) -> EdgeFeatures {
        match kind {
            IndexKind::IvfGen => EdgeFeatures {
                selective_storage: false,
                caching: false,
            },
            IndexKind::IvfGenLoad => EdgeFeatures {
                selective_storage: true,
                caching: false,
            },
            IndexKind::EdgeRag => EdgeFeatures {
                selective_storage: true,
                caching: true,
            },
            other => panic!("EdgeIndex does not implement {other:?}"),
        }
    }
}

pub struct EdgeIndex {
    kind: IndexKind,
    features: EdgeFeatures,
    pub(crate) clusters: ClusterSet,
    pub(crate) source: EmbedSource,
    pub(crate) blob: Option<BlobStore>,
    /// Cost-aware cache behind a read/write lock: searches peek under the
    /// read lock, commits mutate under the write lock.
    pub(crate) cache: Option<RwLock<CostAwareCache>>,
    controller: RwLock<ThresholdController>,
    /// When false the controller's threshold is pinned (Fig. 7 sweeps).
    adaptive: bool,
    pub(crate) scorer: Scorer,
    pub(crate) memory: SharedMemory,
    pub(crate) device: DeviceProfile,
    nprobe: usize,
    /// Online-update state (§5.4): chunks inserted after the initial
    /// build (text + embedding), per-cluster liveness (merged clusters
    /// become tombstones), chunk → cluster routing, and the SLO-derived
    /// storage limit insertions re-evaluate against.
    pub(crate) dynamic: std::collections::HashMap<u32, (String, Vec<f32>)>,
    pub(crate) active: Vec<bool>,
    pub(crate) chunk_cluster: std::collections::HashMap<u32, u32>,
    pub(crate) store_limit: SimDuration,
    /// Bumped by every structural update (insert/remove/split/merge);
    /// lets `commit` drop cache admissions whose embeddings may be stale.
    pub(crate) update_gen: AtomicU64,
    /// Namespace offset for this index's `Region::Cache` ids in the
    /// shared memory model. Zero standalone; shard `i` of a
    /// [`ShardedEdgeIndex`](crate::index::ShardedEdgeIndex) gets
    /// `i << 24` so shards sharing one `MemoryModel` never collide on
    /// their (shard-local) cluster ids.
    pub(crate) region_base: u32,
    /// Memoized first-level snapshot for (batched) lock-free probing;
    /// invalidated by every structural update. See [`ProbeTable`].
    probe_snapshot: RwLock<Option<Arc<ProbeTable>>>,
    /// Structural write-ahead log. `None` for library builds and for the
    /// per-shard indexes inside a [`ShardedEdgeIndex`] (the wrapper owns
    /// the log there); attached by [`EdgeIndex::attach_wal`] *after* any
    /// startup replay so replayed ops are not re-logged.
    pub(crate) wal: Option<Arc<WriteAheadLog>>,
    /// `(parent, new_cluster)` of the most recent committed split, parked
    /// here by `split_cluster` so the caller that triggered it (this
    /// index's own insert path, or the sharded wrapper holding the write
    /// lease) can emit the derived `WalOp::Split` audit record with the
    /// ids it knows (local here, global in the wrapper).
    pub(crate) last_split: Option<(u32, u32)>,
}

/// One probed cluster's candidate hits, tagged with the cluster's
/// position in the global probe order so a sharded merge can reassemble
/// exactly the candidate order a sequential walk would produce.
#[derive(Debug, Clone)]
pub struct ClusterHits {
    /// Position of this cluster in the query's global probe order.
    pub probe_pos: u32,
    /// (chunk id, score) candidates from this cluster, descending.
    pub hits: Vec<(u32, f32)>,
}

/// Result of walking one shard's probed clusters: per-cluster candidate
/// groups plus the deferred cache mutations and modeled costs the walk
/// accumulated. Produced by [`EdgeIndex::search_clusters`].
#[derive(Debug, Clone, Default)]
pub struct ClusterWalk {
    /// Per-cluster candidates in walk (= probe) order.
    pub groups: Vec<ClusterHits>,
    /// Modeled device time of this walk (loads, generation, scans).
    pub ledger: LatencyLedger,
    /// Event counts of this walk.
    pub events: SearchEvents,
    /// Deferred cache mutations for this shard's cache/threshold state.
    pub intent: CacheIntent,
    /// Wall-clock nanoseconds of the walk, measured on the thread that
    /// ran it — 0 unless tracing is enabled. Carried by value so sharded
    /// walks on pool workers can be attributed back to the query's trace
    /// after the fan-in.
    pub walk_ns: u64,
}

impl EdgeIndex {
    /// Build the index. When `selective_storage` is on, clusters whose
    /// profiled gen cost exceeds `store_limit` are embedded now and
    /// persisted to `blob` (Algorithm 1 / Fig. 8 step 7).
    #[allow(clippy::too_many_arguments)]
    pub fn build(
        kind: IndexKind,
        clusters: ClusterSet,
        source: EmbedSource,
        blob: Option<BlobStore>,
        scorer: Scorer,
        memory: SharedMemory,
        device: DeviceProfile,
        retrieval: &RetrievalConfig,
        store_limit: SimDuration,
        slo: SimDuration,
    ) -> Result<Self> {
        let features = EdgeFeatures::for_kind(kind);
        let blob = if features.selective_storage {
            let store = blob.expect("selective storage requires a BlobStore");
            store.clear()?;
            for meta in &clusters.clusters {
                if meta.gen_cost > store_limit && !meta.is_empty() {
                    let emb = source.cluster_embeddings(meta)?;
                    store.put(meta.id, &emb)?;
                }
            }
            Some(store)
        } else {
            None
        };
        let cache = features.caching.then(|| {
            RwLock::new(CostAwareCache::new(
                retrieval.cache_capacity_bytes,
                retrieval.cache_decay,
            ))
        });
        let active = vec![true; clusters.n_clusters()];
        let mut chunk_cluster = std::collections::HashMap::new();
        for meta in &clusters.clusters {
            for &cid in &meta.chunk_ids {
                chunk_cluster.insert(cid, meta.id);
            }
        }
        Ok(EdgeIndex {
            kind,
            features,
            clusters,
            source,
            blob,
            cache,
            controller: RwLock::new(ThresholdController::new(
                retrieval.latency_ewma_alpha,
                retrieval.threshold_step_ms,
                slo.as_millis_f64(),
            )),
            adaptive: true,
            scorer,
            memory,
            device,
            nprobe: retrieval.nprobe,
            dynamic: std::collections::HashMap::new(),
            active,
            chunk_cluster,
            store_limit,
            update_gen: AtomicU64::new(0),
            region_base: 0,
            probe_snapshot: RwLock::new(None),
            wal: None,
            last_split: None,
        })
    }

    /// The shared two-level structure (centroids + per-cluster metadata).
    pub fn clusters(&self) -> &ClusterSet {
        &self.clusters
    }

    /// Namespace a cluster id into the shared memory model (see
    /// `region_base`).
    pub(crate) fn cache_region(&self, c: u32) -> Region {
        Region::Cache(self.region_base | c)
    }

    /// Install this index as shard `base >> 24` of a sharded wrapper:
    /// offsets its memory-model regions out of the other shards' way.
    pub(crate) fn set_region_base(&mut self, base: u32) {
        self.region_base = base;
    }

    /// Aggregate hit/miss/eviction statistics of the embedding cache
    /// (None when this configuration has no cache).
    pub fn cache_stats(&self) -> Option<CacheStats> {
        self.cache.as_ref().map(|c| c.read().unwrap().stats())
    }

    /// Cluster ids currently resident in the embedding cache, sorted
    /// (introspection for equivalence tests and the stats endpoint).
    pub fn cached_clusters(&self) -> Vec<u32> {
        let mut ids: Vec<u32> = self.cache.as_ref().map_or_else(Vec::new, |c| {
            c.read().unwrap().weights().iter().map(|&(id, _)| id).collect()
        });
        ids.sort_unstable();
        ids
    }

    pub fn cache_used_bytes(&self) -> u64 {
        self.cache
            .as_ref()
            .map_or(0, |c| c.read().unwrap().used_bytes())
    }

    /// One cluster's cached embeddings plus their profiled generation
    /// latency, without touching hit/miss statistics (migration export
    /// and rebalance load accounting — see [`CostAwareCache::entry`]).
    pub(crate) fn cached_entry(
        &self,
        cluster: u32,
    ) -> Option<(std::sync::Arc<crate::vecmath::EmbeddingMatrix>, f64)> {
        self.cache
            .as_ref()
            .and_then(|c| c.read().unwrap().entry(cluster))
    }

    /// Total chunk rows across active (non-tombstone) clusters — the
    /// rebalancer's primary per-shard load measure.
    pub fn active_rows(&self) -> u64 {
        self.clusters
            .clusters
            .iter()
            .zip(&self.active)
            .filter(|&(_, &a)| a)
            .map(|(m, _)| m.len() as u64)
            .sum()
    }

    /// Cluster ids currently persisted in this index's blob store
    /// (orphaned-blob invariant checks; empty without selective storage).
    pub fn stored_cluster_ids(&self) -> Vec<u32> {
        self.blob.as_ref().map_or_else(Vec::new, |b| b.cluster_ids())
    }

    pub fn stored_clusters(&self) -> usize {
        self.blob.as_ref().map_or(0, |b| b.len())
    }

    /// This index's blob store, when selective storage is on. Exposed for
    /// the crash-consistency suites, which arm
    /// [`BlobStore::inject_put_failures`] /
    /// [`BlobStore::inject_remove_failures`] to prove the composed
    /// structural ops abort cleanly mid-merge.
    pub fn blob_store(&self) -> Option<&BlobStore> {
        self.blob.as_ref()
    }

    pub fn stored_bytes(&self) -> u64 {
        self.blob.as_ref().map_or(0, |b| b.total_bytes())
    }

    pub fn threshold_ms(&self) -> f64 {
        self.controller.read().unwrap().threshold_ms()
    }

    pub fn set_nprobe(&mut self, nprobe: usize) {
        self.nprobe = nprobe;
    }

    /// Attach a structural write-ahead log. Every structural mutation
    /// from here on appends its record *before* the irreversible step.
    /// Call this after [`EdgeIndex::replay_wal`], never before — replayed
    /// ops must not be re-logged.
    pub fn attach_wal(&mut self, wal: Arc<WriteAheadLog>) {
        self.wal = Some(wal);
    }

    /// The attached WAL, if any (fault-injection suites arm its crash
    /// points through this).
    pub fn wal(&self) -> Option<&Arc<WriteAheadLog>> {
        self.wal.as_ref()
    }

    /// Append `op` to the attached WAL; a no-op without one. Callers
    /// invoke this *before* the mutation the record describes and abort
    /// on error, so the log never lags the index.
    pub(crate) fn wal_append(&self, op: &WalOp) -> Result<()> {
        match &self.wal {
            Some(w) => w.append(op),
            None => Ok(()),
        }
    }

    /// `(parent, new_cluster)` of the most recent committed split, taken
    /// at most once. The sharded wrapper reads this inside the same write
    /// lease as the insert that triggered the split, translates both ids
    /// to global, and emits the `WalOp::Split` audit record.
    pub(crate) fn take_last_split(&mut self) -> Option<(u32, u32)> {
        self.last_split.take()
    }

    /// Rebuild structural state from a recovered WAL op sequence by
    /// driving the ordinary public update path. Only replayable ops are
    /// applied: `Split`/`Merge` are derived audit records (the replayed
    /// inserts/removes re-derive them deterministically) and `Migrate`
    /// has no meaning on a single index. Call on a freshly built index
    /// with no WAL attached; attach the log afterwards.
    pub fn replay_wal(&mut self, ops: &[WalOp]) -> Result<()> {
        for op in ops {
            match op {
                WalOp::Insert { id, text, emb } => {
                    self.insert_chunk(*id, text, emb)?;
                }
                WalOp::Remove { id } => {
                    self.remove_chunk(*id)?;
                }
                WalOp::PinThreshold { ms } => self.pin_threshold(*ms),
                WalOp::Migrate { .. } | WalOp::Split { .. } | WalOp::Merge { .. } => {}
            }
        }
        Ok(())
    }

    /// Pin the caching threshold to a fixed value and disable adaptation
    /// (the Fig. 7 sweep).
    pub fn pin_threshold(&mut self, threshold_ms: f64) {
        // Record-before-mutation: if the WAL refuses the record, leave
        // the threshold untouched rather than mutate unlogged state.
        if self
            .wal_append(&WalOp::PinThreshold { ms: threshold_ms })
            .is_err()
        {
            return;
        }
        self.adaptive = false;
        self.controller.write().unwrap().pin(threshold_ms);
        if let Some(cache) = &self.cache {
            for v in cache.write().unwrap().evict_below(threshold_ms) {
                self.memory.lock().unwrap().release(self.cache_region(v));
            }
        }
    }

    /// Search then immediately apply the cache intent — the single-caller
    /// convenience path (tests, tools). The serving engine calls `search`
    /// and `commit` separately so the commit can observe the query's full
    /// retrieval latency.
    pub fn search_and_commit(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let out = self.search(query, k)?;
        self.commit(&out.intents, out.ledger.retrieval());
        Ok(out)
    }

    /// Gather a cluster's embeddings, consulting the online-update overlay
    /// for chunks inserted after the initial build (§5.4).
    pub(crate) fn gather(&self, c: u32) -> Result<crate::vecmath::EmbeddingMatrix> {
        self.gather_members(&self.clusters.clusters[c as usize])
    }

    /// Gather cluster `c`'s embeddings **as if** member `skip` were
    /// already removed. The blob-first removal path uses this to write
    /// the post-removal blob *before* mutating membership, so a blob
    /// fault aborts the removal with the index untouched.
    pub(crate) fn gather_without(
        &self,
        c: u32,
        skip: u32,
    ) -> Result<crate::vecmath::EmbeddingMatrix> {
        let meta = &self.clusters.clusters[c as usize];
        let remaining = crate::index::ClusterMeta {
            id: meta.id,
            chunk_ids: meta
                .chunk_ids
                .iter()
                .copied()
                .filter(|&id| id != skip)
                .collect(),
            chars: 0,
            gen_cost: crate::simtime::SimDuration::ZERO,
        };
        self.gather_members(&remaining)
    }

    /// The gather body, over an explicit member list (the cluster's own
    /// meta, or a filtered view of it).
    fn gather_members(
        &self,
        meta: &crate::index::ClusterMeta,
    ) -> Result<crate::vecmath::EmbeddingMatrix> {
        if self.dynamic.is_empty() {
            return self.source.cluster_embeddings(meta);
        }
        let dim = self.scorer.dim();
        let mut m = crate::vecmath::EmbeddingMatrix::with_capacity(dim, meta.len());
        // Static members come from the source in one gather; dynamic rows
        // are spliced in positionally.
        let static_meta = crate::index::ClusterMeta {
            id: meta.id,
            chunk_ids: meta
                .chunk_ids
                .iter()
                .copied()
                .filter(|id| !self.dynamic.contains_key(id))
                .collect(),
            chars: 0,
            gen_cost: crate::simtime::SimDuration::ZERO,
        };
        let static_emb = self.source.cluster_embeddings(&static_meta)?;
        let mut si = 0;
        for &cid in &meta.chunk_ids {
            if let Some((_, emb)) = self.dynamic.get(&cid) {
                m.push(emb);
            } else {
                m.push(static_emb.row(si));
                si += 1;
            }
        }
        Ok(m)
    }

    /// Centroid scores with merged-cluster tombstones masked out. The
    /// sharded wrapper splices these per-shard vectors into one global
    /// score table before selecting probes.
    pub(crate) fn probe_scores(&self, query: &[f32]) -> Result<Vec<f32>> {
        let mut scores = self.scorer.scores(query, &self.clusters.centroids)?;
        for (i, s) in scores.iter_mut().enumerate() {
            if !self.active[i] {
                *s = f32::NEG_INFINITY;
            }
        }
        Ok(scores)
    }

    /// Top-`nprobe` clusters for a query (tombstones masked out).
    pub(crate) fn probe(&self, query: &[f32], nprobe: usize) -> Result<Vec<(usize, f32)>> {
        let scores = self.probe_scores(query)?;
        Ok(vecmath::top_k(&scores, scores.len(), nprobe))
    }

    /// Per-cluster liveness flags (tombstones are `false`). Shard probe
    /// snapshots are assembled from this plus [`EdgeIndex::clusters`].
    pub(crate) fn active_flags(&self) -> &[bool] {
        &self.active
    }

    /// Current structural-update generation (probe-snapshot stamping).
    pub(crate) fn update_generation(&self) -> u64 {
        self.update_gen.load(Ordering::Acquire)
    }

    /// Drop the memoized probe snapshot (structural update landed).
    pub(crate) fn invalidate_probe_snapshot(&mut self) {
        *self.probe_snapshot.get_mut().unwrap() = None;
    }

    /// Build a fresh first-level snapshot: for a standalone index the
    /// global id of row `i` is simply `i`.
    fn build_probe_table(&self) -> ProbeTable {
        ProbeTable {
            centroids: self.clusters.centroids.clone(),
            ids: (0..self.clusters.n_clusters() as u32).collect(),
            active: self.active.clone(),
            centroid_bytes: self.clusters.centroid_bytes(),
            generation: self.update_gen.load(Ordering::Acquire),
        }
    }

    /// Walk a set of probed clusters — `(probe position, cluster id)`
    /// pairs in probe order — materializing each per the Fig. 9 chain and
    /// scoring its members. This is the shard unit of work: a standalone
    /// search passes every probed cluster; a
    /// [`ShardedEdgeIndex`](crate::index::ShardedEdgeIndex) passes each
    /// shard its own subsequence, and the preserved `probe_pos` tags let
    /// the merge reassemble exactly the sequential candidate order.
    pub fn search_clusters(
        &self,
        query: &[f32],
        probes: &[(u32, u32)],
        k: usize,
    ) -> Result<ClusterWalk> {
        // Wall-clock the walk only when tracing is on: the two timestamps
        // are branch-local, so the traced-off hot path stays untouched.
        let started = if trace::enabled() {
            Some(std::time::Instant::now())
        } else {
            None
        };
        let mut walk = ClusterWalk {
            intent: CacheIntent {
                generation: self.update_gen.load(Ordering::Acquire),
                ..CacheIntent::default()
            },
            ..ClusterWalk::default()
        };
        let dim = self.scorer.dim();
        for &(pos, c) in probes {
            let ci = c as usize;
            if self.clusters.clusters[ci].is_empty() {
                continue;
            }
            let emb = self.materialize(c, &mut walk.ledger, &mut walk.events, &mut walk.intent)?;
            let meta = &self.clusters.clusters[ci];

            // In-cluster search (Fig. 9 step 6).
            walk.ledger.charge(
                Component::ClusterSearch,
                self.device.mem_scan_cost(meta.emb_bytes(dim)),
            );
            let local = self.scorer.top_k(query, &emb, k)?;
            walk.groups.push(ClusterHits {
                probe_pos: pos,
                hits: local
                    .into_iter()
                    .map(|(li, s)| (meta.chunk_ids[li], s))
                    .collect(),
            });
        }
        if let Some(t0) = started {
            walk.walk_ns = t0.elapsed().as_nanos() as u64;
        }
        Ok(walk)
    }

    /// Shard-walk trace record for a completed [`ClusterWalk`] (empty
    /// vec when tracing is off — no allocation on the untraced path).
    pub(crate) fn walk_records(shard: u32, walk: &ClusterWalk) -> Vec<ShardWalk> {
        if !trace::enabled() {
            return Vec::new();
        }
        vec![ShardWalk {
            shard,
            clusters: walk.groups.len() as u32,
            walk_ns: walk.walk_ns,
            generated: walk.events.generated as u32,
            loaded: walk.events.loaded as u32,
            cache_hits: walk.events.cache_hits as u32,
        }]
    }

    /// Search using centroid scores a caller already computed against a
    /// [`ProbeTable`] snapshot of this index — the batched-probe entry
    /// point ([`crate::sched`] computes `scores` for several queries in
    /// one fused `sim_{A}x{N}` call). Identical to [`VectorIndex::search`]
    /// whenever `scores` equals the index's own masked centroid scores:
    /// the probe charge, probe selection (ties included), cluster walk
    /// and final top-k are the same code paths.
    pub fn search_scored(
        &self,
        query: &[f32],
        table: &ProbeTable,
        scores: &[f32],
        k: usize,
    ) -> Result<SearchOutcome> {
        anyhow::ensure!(
            scores.len() == table.len(),
            "probe scores ({}) must align with the probe table ({})",
            scores.len(),
            table.len()
        );
        let mut ledger = LatencyLedger::new();
        ledger.charge(
            Component::CentroidProbe,
            self.device.mem_scan_cost(table.centroid_bytes),
        );
        let probes = vecmath::top_k(scores, scores.len(), self.nprobe);
        let probed: Vec<u32> = probes.iter().map(|&(i, _)| table.ids[i]).collect();
        let list: Vec<(u32, u32)> = probed
            .iter()
            .enumerate()
            .map(|(pos, &c)| (pos as u32, c))
            .collect();

        let walk = self.search_clusters(query, &list, k)?;
        ledger.merge(&walk.ledger);
        let shard_walks = Self::walk_records(0, &walk);

        let all_hits: Vec<(u32, f32)> = walk.groups.into_iter().flat_map(|g| g.hits).collect();
        let hits = vecmath::top_k_hits(all_hits, k);

        Ok(SearchOutcome {
            hits,
            ledger,
            probed,
            events: walk.events,
            intents: vec![walk.intent],
            shard_walks,
        })
    }

    /// Obtain one probed cluster's embeddings per the Fig. 9 decision
    /// chain, charging the appropriate component. Read-only: cache hits
    /// peek under the read lock; admissions/counter bumps are recorded
    /// into `intent` for the commit path.
    fn materialize(
        &self,
        c: u32,
        ledger: &mut LatencyLedger,
        events: &mut SearchEvents,
        intent: &mut CacheIntent,
    ) -> Result<std::sync::Arc<crate::vecmath::EmbeddingMatrix>> {
        let meta = &self.clusters.clusters[c as usize];
        let dim = self.scorer.dim();
        let emb_bytes = meta.emb_bytes(dim);

        // (2) precomputed in storage?
        if let Some(blob) = &self.blob {
            if blob.contains(c) {
                ledger.charge(
                    Component::StorageLoad,
                    self.device.storage_read_cost(emb_bytes, true),
                );
                events.loaded += 1;
                return Ok(std::sync::Arc::new(blob.get(c)?));
            }
        }

        // (4) embedding cache? Read lock only: concurrent searches don't
        // serialize on cluster scoring.
        if let Some(cache) = &self.cache {
            if let Some(hit) = cache.read().unwrap().peek(c) {
                // Embeddings already in memory: only a residency touch.
                // `hit` is an Arc — no matrix copy on the hot path.
                events.cache_hits += 1;
                ledger.charge(Component::CacheHit, self.device.mem_scan_cost(0));
                self.memory
                    .lock()
                    .unwrap()
                    .touch(self.cache_region(c), hit.bytes());
                intent.accesses.push(CacheAccess::Hit(c));
                return Ok(hit);
            }
            intent.accesses.push(CacheAccess::Miss);
            intent.had_miss = true;
        }

        // (4b) generate online.
        let gen_cost = meta.gen_cost;
        ledger.charge(Component::EmbedGen, gen_cost);
        events.generated += 1;
        let emb = std::sync::Arc::new(self.gather(c)?);

        if self.features.caching {
            // Admission is deferred: the threshold gate and LFU insert run
            // at commit time under the write lock.
            intent.admit.push(AdmitCandidate {
                cluster: c,
                emb: emb.clone(),
                gen_latency_ms: gen_cost.as_millis_f64(),
            });
        }
        Ok(emb)
    }
}

impl VectorIndex for EdgeIndex {
    fn kind(&self) -> IndexKind {
        self.kind
    }

    fn search(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let mut ledger = LatencyLedger::new();

        // (1) centroid probe — first level always resident.
        ledger.charge(
            Component::CentroidProbe,
            self.device.mem_scan_cost(self.clusters.centroid_bytes()),
        );
        let probes = self.probe(query, self.nprobe)?;
        let probed: Vec<u32> = probes.iter().map(|&(ci, _)| ci as u32).collect();
        let list: Vec<(u32, u32)> = probes
            .iter()
            .enumerate()
            .map(|(pos, &(ci, _))| (pos as u32, ci as u32))
            .collect();

        // (2..6) the cluster walk (shared with the sharded path).
        let walk = self.search_clusters(query, &list, k)?;
        ledger.merge(&walk.ledger);
        let shard_walks = Self::walk_records(0, &walk);

        let all_hits: Vec<(u32, f32)> = walk
            .groups
            .into_iter()
            .flat_map(|g| g.hits)
            .collect();
        let hits = vecmath::top_k_hits(all_hits, k);

        Ok(SearchOutcome {
            hits,
            ledger,
            probed,
            events: walk.events,
            intents: vec![walk.intent],
            shard_walks,
        })
    }

    /// Apply each deferred intent in turn. An unsharded search yields
    /// exactly one; the semantics live in [`EdgeIndex::commit_intent`].
    fn commit(&self, intents: &[CacheIntent], retrieval: SimDuration) {
        for intent in intents {
            self.commit_intent(intent, retrieval);
        }
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> u64 {
        // Centroids + per-cluster metadata + cache contents. The pruned
        // second level is the whole point: it does NOT appear here.
        let meta_bytes: u64 = self
            .clusters
            .clusters
            .iter()
            .map(|m| (m.chunk_ids.len() * 4 + 32) as u64)
            .sum();
        self.clusters.centroid_bytes() + meta_bytes + self.cache_used_bytes()
    }

    fn cache_stats(&self) -> Option<CacheStats> {
        EdgeIndex::cache_stats(self)
    }

    fn cache_used_bytes(&self) -> u64 {
        EdgeIndex::cache_used_bytes(self)
    }

    fn cached_clusters(&self) -> Vec<u32> {
        EdgeIndex::cached_clusters(self)
    }

    fn stored_clusters(&self) -> usize {
        EdgeIndex::stored_clusters(self)
    }

    fn stored_bytes(&self) -> u64 {
        EdgeIndex::stored_bytes(self)
    }

    fn threshold_ms(&self) -> f64 {
        EdgeIndex::threshold_ms(self)
    }

    fn pin_threshold(&mut self, threshold_ms: f64) {
        EdgeIndex::pin_threshold(self, threshold_ms)
    }

    fn insert_chunk(&mut self, id: u32, text: &str, emb: &[f32]) -> Result<u32> {
        EdgeIndex::insert_chunk(self, id, text, emb)
    }

    fn remove_chunk(&mut self, id: u32) -> Result<bool> {
        EdgeIndex::remove_chunk(self, id)
    }

    fn wal_checkpoint(&self) -> Result<()> {
        match &self.wal {
            Some(w) => w.checkpoint(),
            None => Ok(()),
        }
    }

    fn wal_stats(&self) -> Option<WalActivity> {
        self.wal.as_ref().map(|w| w.activity())
    }

    fn probe_table(&self) -> Option<Arc<ProbeTable>> {
        if let Some(t) = self.probe_snapshot.read().unwrap().as_ref() {
            return Some(t.clone());
        }
        // Double-checked: another reader may have built it meanwhile.
        let mut slot = self.probe_snapshot.write().unwrap();
        Some(
            slot.get_or_insert_with(|| Arc::new(self.build_probe_table()))
                .clone(),
        )
    }

    fn search_with_scores(
        &self,
        query: &[f32],
        table: &ProbeTable,
        scores: &[f32],
        k: usize,
    ) -> Result<SearchOutcome> {
        // Staleness fence: the lease-based single-shard path probes and
        // walks under one continuous engine read lease, so a snapshot
        // scored before an update must not be combined with a walk after
        // it. Updates here require the engine *write* lease, so a
        // matching generation (checked under this search's read lease)
        // guarantees the snapshot is exactly current; on a mismatch,
        // re-probe in-lease — the unbatched path, correct by
        // construction.
        if table.generation != self.update_gen.load(Ordering::Acquire) {
            return self.search(query, k);
        }
        self.search_scored(query, table, scores, k)
    }
}

impl EdgeIndex {
    /// Apply one shard-intent's deferred cache mutations: LFU counter
    /// bumps for hits, threshold-gated admissions for generated clusters,
    /// then the adaptive-threshold feedback (Alg. 3 observes the query's
    /// total retrieval latency) and its eviction sweep — preserving the
    /// exact sequencing of the old inline path (admission at the
    /// pre-feedback threshold, enforcement after).
    pub fn commit_intent(&self, intent: &CacheIntent, retrieval: SimDuration) {
        let Some(cache) = &self.cache else { return };

        if !intent.accesses.is_empty() {
            // Admissions raced by a structural update are discarded: their
            // gathered embeddings may no longer reflect the cluster.
            let fresh = intent.generation == self.update_gen.load(Ordering::Acquire);
            // Lock order (uniform with `pin_threshold`): controller, then
            // cache, then memory.
            let controller = self.controller.read().unwrap();
            let mut c = cache.write().unwrap();
            // Replay the probes in search order — each hit bumps its LFU
            // counter, each miss advances the decay epoch and (with
            // caching enabled) carries exactly one admission candidate, so
            // counters, epochs and insertion baselines land exactly where
            // the old inline single-threaded path put them.
            let mut admits = intent.admit.iter();
            for access in &intent.accesses {
                match access {
                    CacheAccess::Hit(cl) => c.touch(*cl),
                    CacheAccess::Miss => {
                        c.advance_epoch(1);
                        let Some(cand) = admits.next() else { continue };
                        if !fresh {
                            continue;
                        }
                        if controller.should_cache(cand.gen_latency_ms) {
                            let evicted =
                                c.insert(cand.cluster, cand.emb.clone(), cand.gen_latency_ms);
                            let mut mem = self.memory.lock().unwrap();
                            for v in evicted {
                                mem.release(self.cache_region(v));
                            }
                            // Oversized entries are declined by the cache;
                            // installing them would leak a phantom
                            // resident region nothing could ever release.
                            if c.contains(cand.cluster) {
                                mem.install(self.cache_region(cand.cluster), cand.emb.bytes());
                            }
                        } else {
                            c.note_rejected();
                        }
                    }
                }
            }
        }

        if !self.features.caching || !self.adaptive {
            return;
        }
        self.controller
            .write()
            .unwrap()
            .observe(intent.had_miss, retrieval.as_millis_f64());
        // Enforce the (possibly raised) threshold on current contents.
        let threshold = self.controller.read().unwrap().threshold_ms();
        let evicted = cache.write().unwrap().evict_below(threshold);
        if !evicted.is_empty() {
            let mut mem = self.memory.lock().unwrap();
            for v in evicted {
                mem.release(self.cache_region(v));
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::DatasetProfile;
    use crate::data::Corpus;
    use crate::embedding::{Embedder, EmbedderBackend};
    use crate::index::kmeans::{kmeans, KMeansConfig};
    use crate::index::shared_memory;
    use crate::testutil::shared_compute;
    use crate::vecmath::EmbeddingMatrix;
    use std::sync::Arc;

    struct Fixture {
        corpus: Corpus,
        emb: Arc<EmbeddingMatrix>,
        device: DeviceProfile,
        scorer: Scorer,
        embedder: Embedder,
    }

    fn fixture() -> Fixture {
        let profile = DatasetProfile::tiny();
        let corpus = Corpus::generate(&profile);
        let compute = shared_compute();
        let embedder = Embedder::new(compute.clone(), EmbedderBackend::Projection);
        let emb = Arc::new(embedder.embed_texts(&corpus.texts()).unwrap());
        Fixture {
            corpus,
            emb,
            device: DeviceProfile::jetson_orin_nano(),
            scorer: Scorer::new(compute),
            embedder,
        }
    }

    fn cluster_set(f: &Fixture) -> ClusterSet {
        let km = kmeans(
            &f.emb,
            &KMeansConfig {
                n_clusters: 8,
                iterations: 5,
                seed: 1,
                init: None,
            },
            &f.scorer,
        )
        .unwrap();
        ClusterSet::build(&f.corpus, km.centroids, &km.assignment, &f.device)
    }

    fn blob_dir(tag: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir().join(format!("edgerag-edge-{tag}-{}", std::process::id()));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    fn build(f: &Fixture, kind: IndexKind, tag: &str, store_limit_ms: u64) -> EdgeIndex {
        let set = cluster_set(f);
        let blob = kind
            .uses_storage()
            .then(|| BlobStore::open(&blob_dir(tag), f.scorer.dim()).unwrap());
        EdgeIndex::build(
            kind,
            set,
            EmbedSource::Prebuilt(f.emb.clone()),
            blob,
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            &RetrievalConfig {
                nprobe: 4,
                ..Default::default()
            },
            SimDuration::from_millis(store_limit_ms),
            SimDuration::from_millis(1_000),
        )
        .unwrap()
    }

    #[test]
    fn ivf_gen_always_generates() {
        let f = fixture();
        let idx = build(&f, IndexKind::IvfGen, "gen", 0);
        let q = f.emb.row(3).to_vec();
        let out = idx.search(&q, 5).unwrap();
        assert_eq!(out.events.generated, out.probed.len());
        assert_eq!(out.events.loaded, 0);
        assert_eq!(out.events.cache_hits, 0);
        assert!(out.ledger.component(Component::EmbedGen).as_millis() > 0);
        // No caching: the intent carries nothing to commit.
        assert!(out.intents[0].admit.is_empty());
        assert!(!out.intents[0].had_miss);
    }

    #[test]
    fn matches_ivf_results_exactly() {
        // Paper §6.3.1: EdgeRAG "produces identical retrieval results to
        // the two-level IVF index".
        let f = fixture();
        let set = cluster_set(&f);
        let source = EmbedSource::Prebuilt(f.emb.clone());
        let cluster_embs: Vec<EmbeddingMatrix> = set
            .clusters
            .iter()
            .map(|m| source.cluster_embeddings(m).unwrap())
            .collect();
        let ivf = crate::index::IvfIndex::new(
            cluster_set(&f),
            cluster_embs,
            f.scorer.clone(),
            shared_memory(64 << 20),
            f.device.clone(),
            4,
        );
        let edge = build(&f, IndexKind::EdgeRag, "match", 100);
        for i in [0usize, 17, 101, 300] {
            let q = f.emb.row(i).to_vec();
            let a = ivf.search(&q, 5).unwrap();
            let b = edge.search_and_commit(&q, 5).unwrap();
            let ids_a: Vec<u32> = a.hits.iter().map(|h| h.0).collect();
            let ids_b: Vec<u32> = b.hits.iter().map(|h| h.0).collect();
            assert_eq!(ids_a, ids_b, "query {i}");
        }
    }

    #[test]
    fn live_generation_equals_prebuilt() {
        // The oracle fast path is only legitimate because generation is
        // deterministic: verify Live == Prebuilt end to end.
        let f = fixture();
        let set = cluster_set(&f);
        let meta = set.clusters.iter().find(|m| m.len() >= 3).unwrap();
        let live = EmbedSource::Live {
            embedder: f.embedder.clone(),
            texts: Arc::new(f.corpus.chunks.iter().map(|c| c.text.clone()).collect()),
            batcher: None,
        };
        let pre = EmbedSource::Prebuilt(f.emb.clone());
        let a = live.cluster_embeddings(meta).unwrap();
        let b = pre.cluster_embeddings(meta).unwrap();
        assert_eq!(a.len(), b.len());
        for i in 0..a.len() {
            for (x, y) in a.row(i).iter().zip(b.row(i)) {
                assert!((x - y).abs() < 1e-5);
            }
        }
    }

    #[test]
    fn selective_storage_stores_only_heavy_tail() {
        let f = fixture();
        // store_limit 150ms ≈ the fixture's mean cluster gen cost: only
        // the heavy tail persists.
        let idx = build(&f, IndexKind::IvfGenLoad, "tail", 150);
        let heavy = idx
            .clusters
            .clusters
            .iter()
            .filter(|m| m.gen_cost > SimDuration::from_millis(150) && !m.is_empty())
            .count();
        assert_eq!(idx.stored_clusters(), heavy);
        assert!(heavy > 0, "fixture needs at least one heavy cluster");
        assert!(heavy < idx.clusters.n_clusters(), "not everything stored");
    }

    #[test]
    fn stored_clusters_load_instead_of_generate() {
        let f = fixture();
        let idx = build(&f, IndexKind::IvfGenLoad, "load", 20);
        // Query near a heavy cluster's centroid: find a stored cluster and
        // use one of its member chunks as the query.
        let stored_id = (0..idx.clusters.n_clusters() as u32)
            .find(|&c| idx.blob.as_ref().unwrap().contains(c))
            .unwrap();
        let member = idx.clusters.clusters[stored_id as usize].chunk_ids[0];
        let q = f.emb.row(member as usize).to_vec();
        let out = idx.search(&q, 3).unwrap();
        assert!(out.events.loaded > 0, "no storage loads: {:?}", out.events);
        assert!(out.ledger.component(Component::StorageLoad).as_nanos() > 0);
    }

    #[test]
    fn cache_admission_is_deferred_to_commit() {
        let f = fixture();
        let idx = build(&f, IndexKind::EdgeRag, "defer", 1_000_000);
        let q = f.emb.row(42).to_vec();
        let cold = idx.search(&q, 3).unwrap();
        assert!(cold.events.generated > 0);
        assert!(!cold.intents[0].admit.is_empty());
        // Before commit: nothing was admitted, a repeat search still
        // generates.
        let repeat = idx.search(&q, 3).unwrap();
        assert_eq!(repeat.events.cache_hits, 0);
        // After commit: the repeat hits.
        idx.commit(&cold.intents, cold.ledger.total());
        let warm = idx.search(&q, 3).unwrap();
        assert!(warm.events.cache_hits > 0, "{:?}", warm.events);
    }

    #[test]
    fn cache_hits_on_repeat_queries() {
        let f = fixture();
        let idx = build(&f, IndexKind::EdgeRag, "cache", 1_000_000);
        let q = f.emb.row(42).to_vec();
        let cold = idx.search_and_commit(&q, 3).unwrap();
        let warm = idx.search_and_commit(&q, 3).unwrap();
        assert!(cold.events.generated > 0);
        assert!(warm.events.cache_hits > 0, "{:?}", warm.events);
        assert!(
            warm.ledger.total() < cold.ledger.total(),
            "warm {} !< cold {}",
            warm.ledger.total(),
            cold.ledger.total()
        );
        let stats = idx.cache_stats().unwrap();
        assert!(stats.hits >= 1 && stats.insertions >= 1);
    }

    #[test]
    fn pinned_threshold_rejects_cheap_clusters() {
        let f = fixture();
        let mut idx = build(&f, IndexKind::EdgeRag, "pin", 1_000_000);
        idx.pin_threshold(1e9); // nothing is expensive enough to cache
        let q = f.emb.row(7).to_vec();
        idx.search_and_commit(&q, 3).unwrap();
        let again = idx.search_and_commit(&q, 3).unwrap();
        assert_eq!(again.events.cache_hits, 0);
        assert!(idx.cache_stats().unwrap().rejected_below_threshold > 0);
    }

    #[test]
    fn adaptive_threshold_moves_with_feedback() {
        let f = fixture();
        let idx = build(&f, IndexKind::EdgeRag, "adapt", 1_000_000);
        let q = f.emb.row(9).to_vec();
        assert_eq!(idx.threshold_ms(), 0.0);
        // Simulate slow misses: threshold should rise.
        let out = idx.search(&q, 3).unwrap();
        idx.commit(&out.intents, out.ledger.total());
        for i in 0..5 {
            let q2 = f.emb.row(50 + i * 40).to_vec();
            let out = idx.search(&q2, 3).unwrap();
            idx.commit(
                &out.intents,
                SimDuration::from_millis(2_000 * (i as u64 + 1)),
            );
        }
        assert!(idx.threshold_ms() > 0.0);
    }

    #[test]
    fn stale_admissions_dropped_after_update() {
        // An insert between search and commit bumps the generation; the
        // commit must not admit potentially stale embeddings.
        let f = fixture();
        let mut idx = build(&f, IndexKind::EdgeRag, "stale", 1_000_000);
        let q = f.emb.row(13).to_vec();
        let out = idx.search(&q, 3).unwrap();
        assert!(!out.intents[0].admit.is_empty());
        let text = "late-arriving doc that mutates a cluster zzqstale";
        let emb = f.embedder.embed_one(text).unwrap();
        idx.insert_chunk(90_001, text, &emb).unwrap();
        idx.commit(&out.intents, out.ledger.total());
        // Nothing admitted: the repeat search regenerates.
        let repeat = idx.search(&q, 3).unwrap();
        assert_eq!(repeat.events.cache_hits, 0, "{:?}", repeat.events);
    }

    #[test]
    fn concurrent_searches_agree_with_serial() {
        // The tentpole property: N threads searching one shared index get
        // exactly the hits a serial caller gets, and commits from all
        // threads keep the cache consistent.
        let f = fixture();
        let idx = build(&f, IndexKind::EdgeRag, "conc", 100);
        let queries: Vec<Vec<f32>> = (0..16).map(|i| f.emb.row(i * 25).to_vec()).collect();
        let serial: Vec<Vec<u32>> = queries
            .iter()
            .map(|q| {
                idx.search(q, 5)
                    .unwrap()
                    .hits
                    .iter()
                    .map(|h| h.0)
                    .collect()
            })
            .collect();
        std::thread::scope(|s| {
            for t in 0..4 {
                let idx = &idx;
                let queries = &queries;
                let serial = &serial;
                s.spawn(move || {
                    for round in 0..3 {
                        for (i, q) in queries.iter().enumerate() {
                            let out = idx.search_and_commit(q, 5).unwrap();
                            let ids: Vec<u32> = out.hits.iter().map(|h| h.0).collect();
                            assert_eq!(ids, serial[i], "thread {t} round {round} query {i}");
                        }
                    }
                });
            }
        });
        let stats = idx.cache_stats().unwrap();
        // 4 threads × 3 rounds of the same 16 queries: once one thread's
        // commit admits a cluster, the others' repeats hit it.
        assert!(stats.hits > 0, "{stats:?}");
    }

    #[test]
    fn resident_bytes_far_below_ivf() {
        // The headline memory claim: pruned second level ⇒ resident
        // footprint ≪ total embedding bytes.
        let f = fixture();
        let idx = build(&f, IndexKind::EdgeRag, "mem", 100);
        assert!(idx.resident_bytes() < f.emb.bytes() / 2);
    }
}
