//! Online cross-shard rebalancing: migrate hot clusters between shards
//! without stopping the world.
//!
//! ## Why
//!
//! EdgeRAG's cluster sizes are heavily skewed (paper Fig. 5) — a few fat
//! tail clusters dominate both row count and re-embedding cost. The
//! [`ShardedEdgeIndex`] places clusters round-robin at build time, which
//! balances that skew only *in expectation*, and online inserts/splits
//! make it drift: one shard ends up owning the hot, fat clusters while
//! others idle. This module adds
//!
//! * **per-shard load accounting** ([`ShardedEdgeIndex::cluster_loads`]):
//!   chunk rows plus cached-embedding mass from the cost-LFU cache plus
//!   **probe heat** weighted at [`HEAT_WEIGHT`], per owned cluster
//!   (per-shard probe counters ride along in
//!   [`ShardStats`](crate::index::ShardStats) for observability);
//! * a **planner** ([`plan_rebalance`]): a pure, deterministic greedy
//!   equalizer that proposes at most `max_migrations_per_round` cluster
//!   moves, each strictly reducing the load spread (max − min shard
//!   load). Because heat dominates the weighted load for hot clusters,
//!   equalizing the weighted spread *spreads hot clusters across
//!   shards*; among moves that reduce the spread equally, the planner
//!   prefers the candidate with the highest co-probe affinity to the
//!   receiving shard's residents, *co-locating co-probed clusters*;
//! * an **online migration primitive**
//!   ([`ShardedEdgeIndex::migrate_cluster`]): copy → flip → retire, one
//!   cluster at a time, during which concurrent searches stay
//!   bit-identical to an unsharded oracle (a search sees the cluster on
//!   exactly one shard at every instant).
//!
//! ## The migration state machine
//!
//! ```text
//!  [plan]   no locks; validated again per move
//!    │
//!  [copy]   source shard READ lease: export centroid + metadata +
//!    │      dynamic overlay + blob + cache entry (searches keep flowing)
//!  [import] dest shard WRITE lease: append as a fresh local cluster
//!    │      (invisible: not yet registered in the ownership table)
//!  [flip]   ownership WRITE lock: global id now maps to the destination.
//!    │      Acquiring it drains every in-flight search still holding the
//!    │      ownership READ lock (searches hold it across their walks),
//!    │      so after the flip no search is routed at the source copy.
//!  [retire] source shard WRITE lease: tombstone the copy, release its
//!           blob / cache entry / memory region, bump `update_gen` so
//!           stale in-flight cache admissions are discarded at commit.
//! ```
//!
//! The whole sequence runs under the sharded index's structural-updates
//! mutex, so inserts can never route into a doomed source copy and
//! removes always find exactly one owner. Searches never take that
//! mutex: the only moment a search waits on the rebalancer is a new
//! search blocking briefly behind the flip's ownership write lock — a
//! pointer swap, not the copy (which happened before, under a read
//! lease).
//!
//! See `docs/ARCHITECTURE.md` § "Online rebalancing" for how this sits
//! in the full lock hierarchy and composes with ProbeTable snapshots and
//! the CacheIntent replay invariant.

use std::collections::HashMap;
use std::sync::atomic::Ordering;

use anyhow::Result;

use crate::index::shard::{ShardedEdgeIndex, ORPHAN};
use crate::index::updates::ClusterExport;
use crate::storage::WalOp;

/// How many resident rows one unit of probe heat weighs in the planner's
/// load scalar. Heat decays (halves every `heat_decay_interval_ops`
/// structural updates), so the weighted term tracks *current* traffic:
/// a cluster probed a handful of times recently outweighs a cold fat
/// one, which is exactly the skew EdgeRAG's serving path cares about.
pub const HEAT_WEIGHT: u64 = 4;

/// One cluster's contribution to its shard's load.
#[derive(Debug, Clone, Copy)]
pub struct ClusterLoad {
    /// Global cluster id.
    pub global: u32,
    /// Member chunk rows.
    pub rows: u64,
    /// Embedding rows resident in the shard's cost-LFU cache for this
    /// cluster (0 when not cached) — cached mass migrates with the
    /// cluster, so it counts toward placement.
    pub cached_rows: u64,
    /// Decayed probe-heat counter for this cluster (see
    /// [`ShardedEdgeIndex::cluster_probe_heat`]); weighted by
    /// [`HEAT_WEIGHT`] in the load scalar so hot clusters spread across
    /// shards instead of piling onto one.
    pub heat: u64,
}

impl ClusterLoad {
    /// The scalar the planner equalizes: resident rows plus cached rows
    /// plus heat-weighted probe traffic.
    pub fn load(&self) -> u64 {
        self.rows
            .saturating_add(self.cached_rows)
            .saturating_add(self.heat.saturating_mul(HEAT_WEIGHT))
    }
}

/// One planned migration.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct MigrationMove {
    /// Global cluster id to move.
    pub cluster: u32,
    /// Owning shard at planning time.
    pub from: usize,
    /// Destination shard.
    pub to: usize,
}

/// A bounded set of migrations computed by [`plan_rebalance`].
#[derive(Debug, Clone, Default)]
pub struct MigrationPlan {
    /// Moves in execution order.
    pub moves: Vec<MigrationMove>,
    /// Load spread (max − min shard load) before the plan.
    pub spread_before: u64,
    /// Projected spread after every move lands.
    pub spread_after: u64,
}

/// Outcome of one elastic reshard ([`ShardedEdgeIndex::reshard`]): the
/// shard count before and after, and how many clusters the shrink drain
/// migrated (0 for a grow — fresh shards start empty and fill through
/// later rebalance rounds).
#[derive(Debug, Clone, Copy, Default)]
pub struct ReshardReport {
    /// Shard count before the reshard.
    pub from: usize,
    /// Shard count after.
    pub to: usize,
    /// Clusters migrated off retiring shards by the drain.
    pub migrated: usize,
}

/// Outcome of one rebalance round ([`ShardedEdgeIndex::rebalance`]).
#[derive(Debug, Clone, Copy, Default)]
pub struct RebalanceReport {
    /// Moves the planner proposed this round.
    pub planned: usize,
    /// Moves actually executed.
    pub migrated: usize,
    /// Planned moves skipped at execution time (cluster tombstoned or
    /// re-owned since planning).
    pub skipped: usize,
    /// Load spread when the round started.
    pub spread_before: u64,
    /// Live load spread after the round.
    pub spread_after: u64,
}

/// Compute a bounded, deterministic migration plan over a per-shard load
/// snapshot and a co-probe affinity table. Pure: no locks, no index
/// access — property-tested directly.
///
/// Greedy equalization of the **heat-weighted** load ([`ClusterLoad::load`]):
/// each step moves one cluster from the currently heaviest shard to the
/// currently lightest, choosing the cluster whose load is closest to
/// half the gap (evaluated exactly against the resulting global
/// spread). When both bracketing candidates reduce the spread equally,
/// the one with the higher co-probe affinity to the receiver's current
/// residents wins — co-probed clusters drift together while hot ones
/// spread apart. A step is only taken when it *strictly* reduces the
/// spread, so the projected spread is monotonically non-increasing over
/// the plan and the plan never exceeds `max_moves`. With an empty
/// affinity table the plan is exactly the pre-heat equalizer's.
///
/// Composition with cross-shard merges: a plan draws exclusively from
/// its input snapshot, and [`ShardedEdgeIndex::cluster_loads`] lists
/// only owned, *active* clusters — a merged (tombstoned) cluster can
/// never be scheduled, and a victim's absorbed mass (heat included —
/// merges absorb the dead cluster's heat) is re-accounted the moment
/// the next snapshot is taken. A *stale* plan naming a cluster that
/// merged (or moved) after planning is defused at execution time:
/// [`ShardedEdgeIndex::migrate_cluster`] re-validates liveness and
/// placement under the structural-updates mutex — the same mutex merges
/// hold — and skips the move. `rust/tests/merge_routing.rs` pins both
/// properties.
pub fn plan_rebalance(
    shard_loads: &[Vec<ClusterLoad>],
    affinity: &HashMap<(u32, u32), u64>,
    max_moves: usize,
) -> MigrationPlan {
    let k = shard_loads.len();
    let mut totals: Vec<u64> = shard_loads
        .iter()
        .map(|cs| cs.iter().map(|c| c.load()).sum())
        .collect();
    let spread = |t: &[u64]| -> u64 {
        match (t.iter().max(), t.iter().min()) {
            (Some(max), Some(min)) => max - min,
            _ => 0,
        }
    };
    // Sorted (load, global) candidate lists per shard; ties break toward
    // the lower global id so plans are deterministic.
    let mut avail: Vec<Vec<(u64, u32)>> = shard_loads
        .iter()
        .map(|cs| {
            let mut v: Vec<(u64, u32)> = cs.iter().map(|c| (c.load(), c.global)).collect();
            v.sort_unstable();
            v
        })
        .collect();

    let spread_before = spread(&totals);
    let mut plan = MigrationPlan {
        spread_before,
        spread_after: spread_before,
        ..MigrationPlan::default()
    };
    if k < 2 {
        return plan;
    }

    // Current placement, updated as the plan applies its own moves — the
    // affinity tie-break scores a candidate against the clusters that
    // would actually be its neighbours when the move lands.
    let mut at: HashMap<u32, usize> = shard_loads
        .iter()
        .enumerate()
        .flat_map(|(s, cs)| cs.iter().map(move |c| (c.global, s)))
        .collect();
    // Summed co-probe affinity between `g` and the clusters currently
    // placed on `shard`. The table is bounded (MAX_AFFINITY_PAIRS), so a
    // full scan per candidate is cheap — and keeps this pure.
    let aff_to = |g: u32, shard: usize, at: &HashMap<u32, usize>| -> u64 {
        affinity
            .iter()
            .filter_map(|(&(a, b), &v)| {
                let other = if a == g {
                    b
                } else if b == g {
                    a
                } else {
                    return None;
                };
                (at.get(&other) == Some(&shard)).then_some(v)
            })
            .sum()
    };

    for _ in 0..max_moves {
        let donor = (0..k).max_by_key(|&s| (totals[s], std::cmp::Reverse(s))).unwrap();
        let recv = (0..k).min_by_key(|&s| (totals[s], s)).unwrap();
        if donor == recv || totals[donor] <= totals[recv] || avail[donor].is_empty() {
            break;
        }
        let gap = totals[donor] - totals[recv];
        // Candidates bracketing half the gap: the largest load ≤ gap/2
        // and the smallest load > gap/2. Selection order is fixed, so
        // ties (equal spread, equal affinity) resolve deterministically.
        let cands = &avail[donor];
        let split = cands.partition_point(|&(w, _)| w <= gap / 2);
        let mut best: Option<(u64, u64, usize)> = None; // (spread, affinity, cand index)
        for i in [split.wrapping_sub(1), split] {
            let Some(&(w, g)) = cands.get(i) else { continue };
            if w == 0 {
                continue; // moving an empty cluster changes nothing
            }
            let mut t = totals.clone();
            t[donor] -= w;
            t[recv] += w;
            let s = spread(&t);
            let a = aff_to(g, recv, &at);
            let better = match best {
                None => true,
                // Smaller spread wins; equal spread → the candidate
                // more co-probed with the receiver's residents wins.
                Some((bs, ba, _)) => s < bs || (s == bs && a > ba),
            };
            if better {
                best = Some((s, a, i));
            }
        }
        let Some((new_spread, _, i)) = best else { break };
        if new_spread >= plan.spread_after {
            break; // no candidate strictly improves — stop the round
        }
        let (w, global) = avail[donor].remove(i);
        totals[donor] -= w;
        totals[recv] += w;
        at.insert(global, recv);
        // The moved cluster becomes a candidate on its new shard (a
        // later step of the same plan may move it again).
        let pos = avail[recv].partition_point(|&c| c < (w, global));
        avail[recv].insert(pos, (w, global));
        plan.moves.push(MigrationMove {
            cluster: global,
            from: donor,
            to: recv,
        });
        plan.spread_after = new_spread;
    }
    plan
}

impl ShardedEdgeIndex {
    /// Per-shard load snapshot: one [`ClusterLoad`] per owned, active
    /// cluster (rows + cached mass + decayed probe heat). Takes the
    /// ownership read lock, then the heat table, then one shard read
    /// lease at a time — the hierarchy `shard_stats` uses.
    pub fn cluster_loads(&self) -> Vec<Vec<ClusterLoad>> {
        let own = self.ownership.read().unwrap();
        let heat_rows = self.cluster_probe_heat();
        let heat_of = |g: u32| -> u64 {
            heat_rows
                .binary_search_by_key(&g, |&(id, _)| id)
                .map_or(0, |i| heat_rows[i].1)
        };
        let topo = self.topo();
        let dim = self.scorer.dim().max(1) as u64;
        let mut out = Vec::with_capacity(topo.len());
        for (s, shard) in topo.shards.iter().enumerate() {
            let guard = shard.read().unwrap();
            let mut loads = Vec::new();
            for (l, &g) in own.locals[s].iter().enumerate() {
                if g == ORPHAN || !guard.active_flags()[l] {
                    continue;
                }
                let cached_rows = guard
                    .cached_entry(l as u32)
                    .map_or(0, |(emb, _)| emb.bytes() / (dim * 4));
                loads.push(ClusterLoad {
                    global: g,
                    rows: guard.clusters().clusters[l].len() as u64,
                    cached_rows,
                    heat: heat_of(g),
                });
            }
            out.push(loads);
        }
        out
    }

    /// Current load spread (max − min per-shard load) — the quantity a
    /// rebalance round reduces.
    pub fn load_spread(&self) -> u64 {
        let totals: Vec<u64> = self
            .cluster_loads()
            .iter()
            .map(|cs| cs.iter().map(|c| c.load()).sum())
            .collect();
        match (totals.iter().max(), totals.iter().min()) {
            (Some(max), Some(min)) => max - min,
            _ => 0,
        }
    }

    /// Run one rebalance round: snapshot loads, plan at most
    /// `max_migrations_per_round` moves, execute them one cluster at a
    /// time. Concurrent searches keep serving bit-identical results
    /// throughout (see the module docs). Also reachable through the
    /// server's `{"op":"rebalance"}` and periodically via
    /// `rebalance_interval_ops`. Whole rounds serialize on a dedicated
    /// mutex: concurrent callers queue rather than interleave moves
    /// planned from different load snapshots.
    pub fn rebalance(&self) -> Result<RebalanceReport> {
        let _round = self.rebalance_serial.lock().unwrap();
        let loads = self.cluster_loads();
        let affinity: HashMap<(u32, u32), u64> = self.cluster_affinity().into_iter().collect();
        let plan = plan_rebalance(&loads, &affinity, self.max_migrations);
        let mut report = RebalanceReport {
            planned: plan.moves.len(),
            spread_before: plan.spread_before,
            ..RebalanceReport::default()
        };
        for m in &plan.moves {
            if self.migrate_cluster(m.cluster, m.to)? {
                report.migrated += 1;
            } else {
                report.skipped += 1;
            }
        }
        report.spread_after = self.load_spread();
        Ok(report)
    }

    /// Migrate one cluster (by global id) to `dest`, online. Returns
    /// `Ok(false)` when there is nothing to do (already at `dest`,
    /// unknown id, or tombstoned since planning). Runs the copy → flip →
    /// retire sequence documented in the module docs under the
    /// structural-updates mutex.
    pub fn migrate_cluster(&self, global: u32, dest: usize) -> Result<bool> {
        let _serial = self.updates_serial.lock().unwrap();
        let topo = self.topo(); // stable under the updates mutex
        anyhow::ensure!(dest < topo.len(), "no shard {dest}");
        let Some((src, local)) = self.ownership.read().unwrap().owner_of(global) else {
            return Ok(false);
        };
        if src == dest {
            return Ok(false);
        }

        // Copy: a read lease only — searches of the source shard keep
        // flowing while the snapshot is taken.
        let export = {
            let guard = topo.shards[src].read().unwrap();
            if !guard.active_flags()[local as usize] {
                return Ok(false); // tombstoned since planning
            }
            guard.export_cluster(local)?
        };

        // Record-before-mutation: once the move is known live (owner
        // resolved, source active, export taken), it hits the WAL before
        // the destination imports anything. An append failure aborts
        // with both shards untouched; a crash after the append replays
        // the same (global → dest) move.
        self.wal_append(&WalOp::Migrate {
            global,
            dest: dest as u32,
        })?;

        self.adopt_exported(&export, global, src, local, dest)?;
        Ok(true)
    }

    /// The shared migration tail — import → flip → retire → account —
    /// used by both a plain migration and the composed cross-shard
    /// merge (`ShardedEdgeIndex::remove_chunk`'s migrate-then-merge), so
    /// the two paths cannot drift. `export` was taken from `(src,
    /// local)`; caller holds the structural-updates mutex and no shard
    /// lease. Returns the destination's new local id.
    ///
    /// * **Import**: the destination gains an (as yet unregistered,
    ///   hence invisible) local copy. A failure here leaves every map
    ///   untouched — the migration simply didn't happen.
    /// * **Flip**: from here on every search routes the global id at
    ///   the destination. Acquiring the ownership write lock drains
    ///   in-flight searches still walking under the old mapping.
    /// * **Retire**: no search can reach the source copy any more.
    pub(crate) fn adopt_exported(
        &self,
        export: &ClusterExport,
        global: u32,
        src: usize,
        local: u32,
        dest: usize,
    ) -> Result<u32> {
        let topo = self.topo(); // stable under the updates mutex
        let new_local = topo.shards[dest].write().unwrap().import_cluster(export)?;
        {
            let mut own = self.ownership.write().unwrap();
            own.owner[global as usize] = (dest as u32, new_local);
            own.locals[src][local as usize] = ORPHAN;
            debug_assert_eq!(own.locals[dest].len(), new_local as usize);
            own.locals[dest].push(global);
        }
        topo.shards[src].write().unwrap().retire_cluster(local)?;
        topo.counters[src]
            .migrated_out
            .fetch_add(1, Ordering::Relaxed);
        topo.counters[dest]
            .migrated_in
            .fetch_add(1, Ordering::Relaxed);
        Ok(new_local)
    }

    /// Check every cross-shard structural invariant, quiescing structural
    /// updates first (searches keep running). The randomized churn suite
    /// calls this after every rebalance round.
    ///
    /// * ownership is a bijection: every global id maps to exactly one
    ///   live (shard, local) slot and `locals` agrees with `owner`;
    /// * every shard's local-slot table covers exactly its clusters;
    /// * orphaned slots (migration sources) are tombstoned and hold no
    ///   chunks, no cache entry and no blob;
    /// * chunk routing maps every chunk to an owned, active cluster that
    ///   lists it — and cluster member lists point back at the routing
    ///   table (no lost or duplicated chunks);
    /// * no orphaned cache entries or blobs: both belong to owned,
    ///   active clusters only.
    pub fn verify_integrity(&self) -> Result<()> {
        let _serial = self.updates_serial.lock().unwrap();
        let own = self.ownership.read().unwrap();
        let topo = self.topo(); // stable under the updates mutex
        let k = topo.len();
        anyhow::ensure!(own.locals.len() == k, "locals table covers every shard");

        let mut seen = vec![false; own.owner.len()];
        for (s, slots) in own.locals.iter().enumerate() {
            for (l, &g) in slots.iter().enumerate() {
                if g == ORPHAN {
                    continue;
                }
                let gi = g as usize;
                anyhow::ensure!(gi < own.owner.len(), "local {s}/{l} maps to unknown global {g}");
                anyhow::ensure!(!seen[gi], "global {g} owned by two slots");
                seen[gi] = true;
                anyhow::ensure!(
                    own.owner[gi] == (s as u32, l as u32),
                    "owner[{g}] = {:?} disagrees with locals[{s}][{l}]",
                    own.owner[gi]
                );
            }
        }
        for (g, &s) in seen.iter().enumerate() {
            anyhow::ensure!(s, "global {g} has no owning slot");
        }

        for (s, shard) in topo.shards.iter().enumerate() {
            let guard = shard.read().unwrap();
            let n = guard.clusters().n_clusters();
            anyhow::ensure!(
                own.locals[s].len() == n,
                "shard {s}: {} registered slots for {n} clusters",
                own.locals[s].len()
            );
            let active = guard.active_flags();
            for (l, &g) in own.locals[s].iter().enumerate() {
                if g == ORPHAN {
                    anyhow::ensure!(!active[l], "orphan slot {s}/{l} still active");
                    anyhow::ensure!(
                        guard.clusters().clusters[l].is_empty(),
                        "orphan slot {s}/{l} retains chunks"
                    );
                }
            }
            for c in guard.cached_clusters() {
                let owned = own.global_of(s, c).is_some();
                anyhow::ensure!(owned && active[c as usize], "orphaned cache entry {s}/{c}");
            }
            for c in guard.stored_cluster_ids() {
                let owned = own.global_of(s, c).is_some();
                anyhow::ensure!(owned && active[c as usize], "orphaned blob {s}/{c}");
            }
            // Chunk routing ⇄ member lists agree, with no strays.
            let mut routed = 0usize;
            for (&chunk, &c) in guard.chunk_cluster.iter() {
                anyhow::ensure!(
                    own.global_of(s, c).is_some() && active[c as usize],
                    "chunk {chunk} routed to unowned cluster {s}/{c}"
                );
                anyhow::ensure!(
                    guard.clusters().clusters[c as usize].chunk_ids.contains(&chunk),
                    "chunk {chunk} not listed by its cluster {s}/{c}"
                );
                routed += 1;
            }
            let listed: usize = own.locals[s]
                .iter()
                .enumerate()
                .filter(|&(_, &g)| g != ORPHAN)
                .map(|(l, _)| guard.clusters().clusters[l].len())
                .sum();
            anyhow::ensure!(
                routed == listed,
                "shard {s}: {routed} routed chunks vs {listed} listed members"
            );
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::testutil::test_seed;

    fn apply(plan: &MigrationPlan, loads: &[Vec<ClusterLoad>]) -> Vec<u64> {
        let mut totals: Vec<u64> = loads
            .iter()
            .map(|cs| cs.iter().map(|c| c.load()).sum())
            .collect();
        let weight = |g: u32| -> u64 {
            loads
                .iter()
                .flatten()
                .find(|c| c.global == g)
                .map(|c| c.load())
                .unwrap()
        };
        for m in &plan.moves {
            let w = weight(m.cluster);
            totals[m.from] -= w;
            totals[m.to] += w;
        }
        totals
    }

    /// Random loads with heat included: every property below holds for
    /// the heat-weighted scalar exactly as it did for rows+cached.
    fn random_loads(rng: &mut Rng, shards: usize) -> Vec<Vec<ClusterLoad>> {
        let mut g = 0u32;
        (0..shards)
            .map(|_| {
                (0..rng.below(12))
                    .map(|_| {
                        g += 1;
                        ClusterLoad {
                            global: g,
                            rows: rng.below(200) as u64,
                            cached_rows: rng.below(50) as u64,
                            heat: rng.below(40) as u64,
                        }
                    })
                    .collect()
            })
            .collect()
    }

    /// Random (bounded) co-probe affinity over the snapshot's globals.
    fn random_affinity(rng: &mut Rng, loads: &[Vec<ClusterLoad>]) -> HashMap<(u32, u32), u64> {
        let globals: Vec<u32> = loads.iter().flatten().map(|c| c.global).collect();
        let mut aff = HashMap::new();
        if globals.len() < 2 {
            return aff;
        }
        for _ in 0..rng.below(24) {
            let a = globals[rng.below(globals.len())];
            let b = globals[rng.below(globals.len())];
            if a != b {
                *aff.entry((a.min(b), a.max(b))).or_insert(0) += rng.below(16) as u64 + 1;
            }
        }
        aff
    }

    #[test]
    fn plan_never_exceeds_migration_budget() {
        let mut rng = Rng::new(test_seed(0xBA1A));
        for _ in 0..200 {
            let shards = rng.range(1, 6);
            let max_moves = rng.below(5);
            let loads = random_loads(&mut rng, shards);
            let aff = random_affinity(&mut rng, &loads);
            let plan = plan_rebalance(&loads, &aff, max_moves);
            assert!(plan.moves.len() <= max_moves, "{plan:?}");
        }
    }

    #[test]
    fn plan_spread_is_monotone_and_projection_is_exact() {
        let mut rng = Rng::new(test_seed(0x5EED));
        for case in 0..200 {
            let shards = rng.range(2, 6);
            let loads = random_loads(&mut rng, shards);
            let aff = random_affinity(&mut rng, &loads);
            let plan = plan_rebalance(&loads, &aff, 8);
            assert!(
                plan.spread_after <= plan.spread_before,
                "case {case}: spread grew: {plan:?}"
            );
            if !plan.moves.is_empty() {
                assert!(
                    plan.spread_after < plan.spread_before,
                    "case {case}: moves without strict improvement: {plan:?}"
                );
            }
            // A prefix-by-prefix replay reproduces the projected spread.
            let totals = apply(&plan, &loads);
            let spread = totals.iter().max().unwrap() - totals.iter().min().unwrap();
            assert_eq!(spread, plan.spread_after, "case {case}: {plan:?}");
            // Every move names a cluster the donor actually held (in
            // plan order, accounting for earlier moves).
            let mut at: std::collections::HashMap<u32, usize> = loads
                .iter()
                .enumerate()
                .flat_map(|(s, cs)| cs.iter().map(move |c| (c.global, s)))
                .collect();
            for m in &plan.moves {
                assert_eq!(at.get(&m.cluster), Some(&m.from), "case {case}: {m:?}");
                at.insert(m.cluster, m.to);
            }
        }
    }

    #[test]
    fn plan_draws_only_from_its_snapshot() {
        // The merge-composition guarantee at the planner level: a plan
        // can only name clusters present in its input snapshot, so a
        // load snapshot that excludes merging/tombstoned clusters (as
        // `cluster_loads` does) yields a plan that cannot touch them.
        let mut rng = Rng::new(test_seed(0x9E64));
        for case in 0..200 {
            let shards = rng.range(2, 6);
            let loads = random_loads(&mut rng, shards);
            let known: std::collections::HashSet<u32> =
                loads.iter().flatten().map(|c| c.global).collect();
            let aff = random_affinity(&mut rng, &loads);
            let plan = plan_rebalance(&loads, &aff, 8);
            for m in &plan.moves {
                assert!(
                    known.contains(&m.cluster),
                    "case {case}: planned unknown cluster {}: {plan:?}",
                    m.cluster
                );
            }
        }
    }

    #[test]
    fn plan_is_deterministic() {
        let seed = test_seed(0xD00D);
        let mk = || {
            let mut rng = Rng::new(seed);
            let loads = random_loads(&mut rng, 4);
            let aff = random_affinity(&mut rng, &loads);
            plan_rebalance(&loads, &aff, 6)
        };
        let (a, b) = (mk(), mk());
        assert_eq!(a.moves, b.moves);
        assert_eq!(a.spread_after, b.spread_after);
    }

    #[test]
    fn skewed_load_plans_toward_balance() {
        // One shard holds everything: a round must move work off it.
        let loads = vec![
            vec![
                ClusterLoad { global: 0, rows: 100, cached_rows: 0, heat: 0 },
                ClusterLoad { global: 1, rows: 90, cached_rows: 10, heat: 0 },
                ClusterLoad { global: 2, rows: 80, cached_rows: 0, heat: 0 },
                ClusterLoad { global: 3, rows: 10, cached_rows: 0, heat: 0 },
            ],
            vec![],
            vec![],
        ];
        let plan = plan_rebalance(&loads, &HashMap::new(), 3);
        assert!(!plan.moves.is_empty());
        assert!(plan.spread_after < plan.spread_before / 2, "{plan:?}");
        assert!(plan.moves.iter().all(|m| m.from == 0));
    }

    #[test]
    fn heat_only_spread_decreases_monotonically() {
        // The heat-spread half of the tentpole objective, isolated: with
        // rows = cached = 0 the load scalar is HEAT_WEIGHT × heat, so
        // the plan's strict spread decrease IS a strict heat-spread
        // decrease — hot clusters spread out, never pile up.
        let mut rng = Rng::new(test_seed(0x4EA7));
        for case in 0..200 {
            let shards = rng.range(2, 6);
            let mut loads = random_loads(&mut rng, shards);
            for c in loads.iter_mut().flatten() {
                c.rows = 0;
                c.cached_rows = 0;
            }
            let heat_spread = |totals: &[u64]| -> u64 {
                match (totals.iter().max(), totals.iter().min()) {
                    (Some(max), Some(min)) => max - min,
                    _ => 0,
                }
            };
            let plan = plan_rebalance(&loads, &HashMap::new(), 8);
            assert!(plan.spread_after <= plan.spread_before, "case {case}: {plan:?}");
            if !plan.moves.is_empty() {
                assert!(plan.spread_after < plan.spread_before, "case {case}: {plan:?}");
            }
            // Projection is exact in heat units too.
            let totals = apply(&plan, &loads);
            assert_eq!(
                heat_spread(&totals),
                plan.spread_after,
                "case {case}: {plan:?}"
            );
            assert_eq!(plan.spread_before % HEAT_WEIGHT, 0, "pure-heat loads");
        }
    }

    #[test]
    fn plan_never_moves_merged_or_tombstoned_clusters() {
        // cluster_loads excludes tombstoned clusters from the snapshot;
        // the plan must never resurrect one — even when the affinity
        // table still holds edges naming it (merge re-keying is
        // best-effort and decay-pruned, so stale edges can linger).
        let mut rng = Rng::new(test_seed(0x70B5));
        for case in 0..200 {
            let shards = rng.range(2, 6);
            let mut loads = random_loads(&mut rng, shards);
            let mut aff = random_affinity(&mut rng, &loads);
            // Tombstone roughly a third of the clusters: drop them from
            // the snapshot, but leave their affinity edges in place.
            let mut tombstoned = std::collections::HashSet::new();
            for cs in loads.iter_mut() {
                cs.retain(|c| {
                    if c.global % 3 == 0 {
                        tombstoned.insert(c.global);
                        false
                    } else {
                        true
                    }
                });
            }
            for (i, &g) in tombstoned.iter().enumerate().take(4) {
                aff.insert((g.min(i as u32 + 1), g.max(i as u32 + 1)), 9);
            }
            let plan = plan_rebalance(&loads, &aff, 8);
            for m in &plan.moves {
                assert!(
                    !tombstoned.contains(&m.cluster),
                    "case {case}: planned tombstoned cluster {}: {plan:?}",
                    m.cluster
                );
            }
        }
    }

    #[test]
    fn affinity_breaks_equal_spread_ties_toward_coprobed_receiver() {
        // Two donor candidates produce the same resulting spread (move 8
        // or move 12 out of 20 → spread 4 either way). Without affinity
        // the bracket's first candidate (global 1, load 8) wins; with an
        // edge between global 2 and the receiver's resident global 3,
        // the co-probed cluster must win instead.
        let loads = vec![
            vec![
                ClusterLoad { global: 1, rows: 8, cached_rows: 0, heat: 0 },
                ClusterLoad { global: 2, rows: 12, cached_rows: 0, heat: 0 },
            ],
            vec![ClusterLoad { global: 3, rows: 0, cached_rows: 0, heat: 0 }],
        ];
        let neutral = plan_rebalance(&loads, &HashMap::new(), 1);
        assert_eq!(neutral.moves.len(), 1);
        assert_eq!(neutral.moves[0].cluster, 1, "{neutral:?}");

        let mut aff = HashMap::new();
        aff.insert((2u32, 3u32), 5u64);
        let steered = plan_rebalance(&loads, &aff, 1);
        assert_eq!(steered.moves.len(), 1);
        assert_eq!(steered.moves[0].cluster, 2, "{steered:?}");
        assert_eq!(
            steered.spread_after, neutral.spread_after,
            "the tie-break never trades spread for affinity"
        );
    }
}
