//! Flat index baseline: every chunk embedding in one array, every query a
//! full linear scan (paper §2.3). Accurate but memory-hungry — the Fig. 3
//! motivation case.

use std::sync::Arc;

use anyhow::Result;

use crate::config::{DeviceProfile, IndexKind};
use crate::index::{Scorer, SearchOutcome, SharedMemory, VectorIndex};
use crate::simtime::{Component, LatencyLedger};
use crate::storage::{Region, PAGE_BYTES};
use crate::vecmath::EmbeddingMatrix;

/// The exhaustive-scan baseline (Table 4 row "Flat").
pub struct FlatIndex {
    emb: Arc<EmbeddingMatrix>,
    scorer: Scorer,
    memory: SharedMemory,
    device: DeviceProfile,
}

impl FlatIndex {
    /// Wrap a prebuilt embedding matrix; call [`FlatIndex::preload`] to
    /// model its residency.
    pub fn new(
        emb: Arc<EmbeddingMatrix>,
        scorer: Scorer,
        memory: SharedMemory,
        device: DeviceProfile,
    ) -> Self {
        FlatIndex {
            emb,
            scorer,
            memory,
            device,
        }
    }

    /// Number of indexed chunks.
    pub fn len(&self) -> usize {
        self.emb.len()
    }

    /// True when the index holds no chunks.
    pub fn is_empty(&self) -> bool {
        self.emb.is_empty()
    }

    /// Load the embedding array into (modeled) memory — the flat
    /// baseline's startup premise (Table 4: embeddings in Memory).
    pub fn preload(&self) {
        let mut mem = self.memory.lock().unwrap();
        mem.touch_paged(Region::FlatPage, self.emb.bytes());
    }
}

impl VectorIndex for FlatIndex {
    fn kind(&self) -> IndexKind {
        IndexKind::Flat
    }

    fn search(&self, query: &[f32], k: usize) -> Result<SearchOutcome> {
        let mut ledger = LatencyLedger::new();
        let bytes = self.emb.bytes();

        // Residency: the scan walks the whole array; pages not resident
        // fault in at sequential storage rate (the scan is sequential).
        let faulted = {
            let mut mem = self.memory.lock().unwrap();
            mem.touch_paged(Region::FlatPage, bytes)
        };
        let mut events = super::SearchEvents::default();
        if faulted > 0 {
            events.thrash_faults = faulted.div_ceil(PAGE_BYTES) as usize;
            ledger.charge(
                Component::Thrash,
                self.device.storage_read_cost(faulted, true),
            );
        }

        // The scan itself: memory-bandwidth-bound similarity over all rows.
        ledger.charge(Component::ClusterSearch, self.device.mem_scan_cost(bytes));

        // Real numerics through the PJRT similarity kernel.
        let top = self.scorer.top_k(query, &self.emb, k)?;
        let hits = top.into_iter().map(|(i, s)| (i as u32, s)).collect();

        Ok(SearchOutcome {
            hits,
            ledger,
            probed: Vec::new(),
            events,
            intents: Vec::new(),
            shard_walks: Vec::new(),
        })
    }

    fn as_any(&self) -> &dyn std::any::Any {
        self
    }

    fn as_any_mut(&mut self) -> &mut dyn std::any::Any {
        self
    }

    fn resident_bytes(&self) -> u64 {
        self.emb.bytes()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Rng;
    use crate::index::shared_memory;
    use crate::testutil::shared_compute;

    fn rows(dim: usize, n: usize, seed: u64) -> EmbeddingMatrix {
        let mut rng = Rng::new(seed);
        let mut m = EmbeddingMatrix::new(dim);
        for _ in 0..n {
            let mut row: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let norm = crate::vecmath::l2_norm(&row);
            for v in &mut row {
                *v /= norm;
            }
            m.push(&row);
        }
        m
    }

    #[test]
    fn finds_planted_match_and_charges_scan() {
        let scorer = Scorer::new(shared_compute());
        let dim = scorer.dim();
        let mut m = rows(dim, 500, 1);
        let q: Vec<f32> = m.row(77).to_vec();
        m.data[77 * dim] += 0.0; // identity row
        let idx = FlatIndex::new(
            Arc::new(m),
            scorer,
            shared_memory(1 << 30),
            DeviceProfile::jetson_orin_nano(),
        );
        let out = idx.search(&q, 3).unwrap();
        assert_eq!(out.hits[0].0, 77);
        assert!(out.ledger.component(Component::ClusterSearch).as_nanos() > 0);
    }

    #[test]
    fn thrashes_when_larger_than_memory() {
        let scorer = Scorer::new(shared_compute());
        let dim = scorer.dim();
        let n = 4096; // 4 MiB of embeddings @ dim 256
        let m = Arc::new(rows(dim, n, 2));
        let small_mem = shared_memory(1 << 20); // 1 MiB budget
        let idx = FlatIndex::new(
            m,
            scorer,
            small_mem,
            DeviceProfile::jetson_orin_nano(),
        );
        let q = vec![0.1f32; dim];
        let a = idx.search(&q, 1).unwrap();
        let b = idx.search(&q, 1).unwrap();
        // Every scan must fault (working set 4× capacity) — sustained
        // thrash, not just a cold start.
        assert!(a.ledger.component(Component::Thrash).as_millis() > 0);
        assert!(b.ledger.component(Component::Thrash).as_millis() > 0);
        assert!(b.events.thrash_faults > 0);
    }

    #[test]
    fn no_thrash_when_fits() {
        let scorer = Scorer::new(shared_compute());
        let dim = scorer.dim();
        let m = Arc::new(rows(dim, 512, 3));
        let idx = FlatIndex::new(
            m,
            scorer,
            shared_memory(64 << 20),
            DeviceProfile::jetson_orin_nano(),
        );
        let q = vec![0.1f32; dim];
        idx.search(&q, 1).unwrap(); // cold faults
        let warm = idx.search(&q, 1).unwrap();
        assert_eq!(warm.ledger.component(Component::Thrash).as_nanos(), 0);
    }
}
