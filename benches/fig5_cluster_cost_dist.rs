//! Bench E4 — paper Fig. 5: distribution of per-cluster embedding
//! generation cost on the nq-like profile (tail-heavy shape).

mod common;

fn main() -> anyhow::Result<()> {
    let ctx = common::ctx();
    edgerag::eval::experiments::fig5(&ctx, "nq")?;
    Ok(())
}
