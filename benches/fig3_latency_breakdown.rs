//! Bench E2 — paper Fig. 3: RAG latency breakdown (retrieval / first
//! token) and embedded DB size vs device memory, Flat vs IVF, across the
//! BEIR-suite profiles. Run: `cargo bench --bench fig3_latency_breakdown`
//! (`-- --full` for the complete workloads).

mod common;

fn main() -> anyhow::Result<()> {
    let ctx = common::ctx();
    edgerag::eval::experiments::fig3(&ctx)?;
    Ok(())
}
