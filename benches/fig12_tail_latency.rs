//! Bench E8/E11 — paper Fig. 12 + §6.3.3: retrieval-latency distribution
//! per optimization stage (IVF → +gen → +load → +cache) on the nq-like
//! profile, with the p95 reduction factors the paper reports.

mod common;

fn main() -> anyhow::Result<()> {
    let ctx = common::ctx();
    edgerag::eval::experiments::fig12(&ctx, "nq")?;
    edgerag::eval::experiments::breakdown(&ctx, "nq")?;
    Ok(())
}
