//! Bench E3 — paper Fig. 4: embedding-generation rate vs storage-load
//! rate across cluster sizes (the ~24 kB crossover), plus a grounding
//! measurement of the real PJRT embedding path's throughput.

mod common;

use edgerag::embedding::{Embedder, EmbedderBackend};

fn main() -> anyhow::Result<()> {
    let ctx = common::ctx();
    edgerag::eval::experiments::fig4(&ctx)?;

    // Grounding: real embeddings/second through the three-layer stack
    // (this testbed's CPU, not the modeled Jetson — reported for context).
    let embedder = Embedder::new(ctx.builder.compute.clone(), EmbedderBackend::Projection);
    let texts: Vec<String> = (0..64)
        .map(|i| format!("chunk {i} with some words w{} w{} w{}", i % 7, i % 13, i % 29))
        .collect();
    let refs: Vec<&str> = texts.iter().map(|s| s.as_str()).collect();
    let (mean, p50, p95) = common::time(2, 10, || {
        embedder.embed_texts(&refs).unwrap();
    });
    println!(
        "grounding: real PJRT embed of 64 chunks — mean {} p50 {} p95 {} ({:.0} chunks/s on this testbed)",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95),
        64.0 / (mean as f64 / 1e9),
    );
    Ok(())
}
