//! Bench E9/E10 — paper Fig. 13 + headline: TTFT for all five index
//! configurations across all datasets, with the paper's aggregate
//! speedups (1.8× avg, 3.82× large).

mod common;

fn main() -> anyhow::Result<()> {
    let ctx = common::ctx();
    edgerag::eval::experiments::fig13(&ctx)?;
    edgerag::eval::experiments::headline(&ctx)?;
    Ok(())
}
