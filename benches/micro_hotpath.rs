//! L3 micro benches: wall-clock cost of the coordinator hot paths that sit
//! in front of every PJRT call — cache access/insert, top-k selection,
//! tokenizer featurization, centroid-probe masking, memory-model touch,
//! JSON protocol encode/decode — plus the scalar-vs-SIMD A/B legs for
//! the reference kernels (`dot`, `sim`, `proj`). These are the perf-pass
//! targets: the coordinator must be invisible next to the modeled device
//! latencies (§Perf in EXPERIMENTS.md).
//!
//! The A/B results are recorded to the machine-readable trajectory
//! (`BENCH_8.json`, section `micro_hotpath`) — validate with
//! `edgerag bench-validate`. `--smoke` shrinks shapes/iterations for CI.

mod common;

use edgerag::cache::CostAwareCache;
use edgerag::data::Rng;
use edgerag::embedding::tokenizer;
use edgerag::json;
use edgerag::runtime::reference::RefCompute;
use edgerag::runtime::{Manifest, Tensor};
use edgerag::storage::{MemoryModel, Region};
use edgerag::testutil::artifacts_dir;
use edgerag::vecmath::{self, EmbeddingMatrix};
use std::sync::Arc;

/// Untiled scalar-dot similarity — the retired implementation, kept
/// here as the A/B baseline for the cache-blocked lane-reduction kernel.
fn sim_scalar(q: &[f32], rows: &[f32], a: usize, n: usize, d: usize) -> Vec<f32> {
    let mut out = vec![0.0f32; a * n];
    for i in 0..a {
        for j in 0..n {
            out[i * n + j] = vecmath::dot_scalar(&q[i * d..(i + 1) * d], &rows[j * d..(j + 1) * d]);
        }
    }
    out
}

/// Projection rows over synthetic weights; `simd` toggles the inner
/// accumulation between the scalar loop (retired) and `vecmath::axpy`
/// (shipped) — identical data, so the ratio isolates the unroll.
fn proj_rows(feats: &[f32], dims: (usize, usize, usize), w: &[f32], bias: &[f32], simd: bool) -> Vec<f32> {
    let (b, vocab, dim) = dims;
    let mut out = vec![0.0f32; b * dim];
    for r in 0..b {
        let frow = &feats[r * vocab..(r + 1) * vocab];
        let orow = &mut out[r * dim..(r + 1) * dim];
        orow.copy_from_slice(bias);
        for (v, &f) in frow.iter().enumerate() {
            if f != 0.0 {
                let wrow = &w[v * dim..(v + 1) * dim];
                if simd {
                    vecmath::axpy(f, wrow, orow);
                } else {
                    for (o, &x) in orow.iter_mut().zip(wrow) {
                        *o += f * x;
                    }
                }
            }
        }
        let norm = (orow.iter().map(|x| (x * x) as f64).sum::<f64>() + 1e-6).sqrt() as f32;
        for o in orow.iter_mut() {
            *o /= norm;
        }
    }
    out
}

fn emb(rows: usize, dim: usize) -> Arc<EmbeddingMatrix> {
    let mut rng = Rng::new(7);
    let mut m = EmbeddingMatrix::new(dim);
    for _ in 0..rows {
        let row: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        m.push(&row);
    }
    Arc::new(m)
}

fn main() {
    println!("== L3 micro hot paths (wall clock, this testbed) ==");

    // 1. cost-aware cache access (hit) + decay sweep at realistic size
    let mut cache = CostAwareCache::new(64 << 20, 0.9);
    for c in 0..200u32 {
        cache.insert(c, emb(64, 256), 100.0 + c as f64);
    }
    let (mean, p50, p95) = common::time(100, 3000, || {
        std::hint::black_box(cache.access(97));
    });
    println!(
        "cache access (200 entries, hit + decay): mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 2. cache insert with eviction pressure
    let mut cache2 = CostAwareCache::new(4 << 20, 0.9);
    let block = emb(64, 256);
    let mut id = 0u32;
    let (mean, p50, p95) = common::time(50, 1000, || {
        cache2.insert(id, block.clone(), 50.0);
        id += 1;
    });
    println!(
        "cache insert+evict (4 MiB cap): mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 3. top-k over a 4096-score slab (the post-kernel selection)
    let mut rng = Rng::new(3);
    let scores: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
    let (mean, p50, p95) = common::time(100, 5000, || {
        std::hint::black_box(vecmath::top_k(&scores, 4096, 5));
    });
    println!(
        "top-k(5) of 4096 scores: mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 4. tokenizer featurization of a 256-char chunk
    let text = "the quick brown fox jumps over the lazy dog ".repeat(6);
    let mut buf = vec![0.0f32; tokenizer::VOCAB];
    let (mean, p50, p95) = common::time(100, 5000, || {
        tokenizer::features_into(&text, &mut buf);
    });
    println!(
        "tokenize+featurize 256-char chunk: mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 5. memory-model touch (hit path)
    let mut mm = MemoryModel::new(1 << 30);
    for c in 0..500u32 {
        mm.touch(Region::Cluster(c), 64 << 10);
    }
    let (mean, p50, p95) = common::time(100, 5000, || {
        std::hint::black_box(mm.touch(Region::Cluster(250), 64 << 10));
    });
    println!(
        "memory-model touch (hit, 500 regions): mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 6. server JSON round-trip encode+decode of a query response
    let resp = json::Value::object(vec![
        ("hits", json::Value::array((0..5).map(|i| {
            json::Value::object(vec![("chunk", (i as u64).into()), ("score", 0.73.into())])
        }))),
        ("retrieval_ms", 123.456.into()),
        ("ttft_ms", 456.789.into()),
    ]);
    let (mean, p50, p95) = common::time(100, 5000, || {
        let s = resp.to_string();
        std::hint::black_box(json::parse(&s).unwrap());
    });
    println!(
        "JSON response encode+parse: mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 7. end-to-end coordinator overhead: one full pipeline.handle minus
    //    the PJRT time is hard to isolate; instead report handle() wall
    //    time on the tiny dataset as the upper bound.
    let ctx = common::ctx();
    let built = ctx.build("tiny").expect("build tiny");
    let pipeline = ctx
        .builder
        .pipeline(&built, edgerag::config::IndexKind::EdgeRag)
        .unwrap();
    let queries: Vec<String> = built
        .workload
        .queries
        .iter()
        .take(16)
        .map(|q| q.text.clone())
        .collect();
    let mut qi = 0;
    let (mean, p50, p95) = common::time(4, 64, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(pipeline.handle(q).unwrap());
    });
    println!(
        "pipeline.handle (tiny, incl. PJRT): mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 8. scalar-vs-SIMD A/B: the retired scalar kernels against the
    //    shipped lane-reduction dot, cache-blocked sim and unrolled
    //    axpy. Identical inputs per pair; results recorded to the
    //    trajectory so speedups are tracked release over release.
    println!("\n== kernel A/B: retired scalar vs shipped SIMD reference ==");
    let smoke = common::smoke();
    let manifest = Manifest::load(&artifacts_dir())
        .unwrap_or_else(|_| Manifest::builtin(&artifacts_dir()));
    let refc = RefCompute::new(&manifest);
    let dim = manifest.dim;
    let mut rng = Rng::new(42);
    let mut kernels: Vec<(&str, json::Value)> = Vec::new();
    let entry = |mean: u64, p50: u64, p95: u64| {
        json::Value::object(vec![
            ("mean_ns", mean.into()),
            ("p50_ns", p50.into()),
            ("p95_ns", p95.into()),
        ])
    };

    // dot: 256 vector pairs per iteration so timer overhead amortizes.
    let pairs: Vec<(Vec<f32>, Vec<f32>)> = (0..256)
        .map(|_| {
            let a: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            let b: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
            (a, b)
        })
        .collect();
    let iters = if smoke { 100 } else { 2000 };
    let (m_sc, p50_sc, p95_sc) = common::time(iters / 10, iters, || {
        let mut acc = 0.0f32;
        for (a, b) in &pairs {
            acc += vecmath::dot_scalar(a, b);
        }
        std::hint::black_box(acc);
    });
    let (m_sd, p50_sd, p95_sd) = common::time(iters / 10, iters, || {
        let mut acc = 0.0f32;
        for (a, b) in &pairs {
            acc += vecmath::dot(a, b);
        }
        std::hint::black_box(acc);
    });
    let dot_speedup = m_sc as f64 / m_sd.max(1) as f64;
    println!(
        "dot ({dim}-dim, 256 pairs): scalar mean {} vs simd mean {} (×{dot_speedup:.2})",
        common::fmt_ns(m_sc),
        common::fmt_ns(m_sd)
    );
    kernels.push(("dot_scalar", entry(m_sc, p50_sc, p95_sc)));
    kernels.push(("dot_simd", entry(m_sd, p50_sd, p95_sd)));

    // sim: scalar naive double loop vs the production cache-blocked
    // kernel (RefCompute::run, bit-identical output ordering).
    let (a, n) = if smoke { (8, 512) } else { (32, 2048) };
    let q: Vec<f32> = (0..a * dim).map(|_| rng.normal() as f32).collect();
    let rows: Vec<f32> = (0..n * dim).map(|_| rng.normal() as f32).collect();
    let sim_inputs = [
        Tensor::F32(q.clone(), vec![a, dim]),
        Tensor::F32(rows.clone(), vec![n, dim]),
    ];
    let iters = if smoke { 5 } else { 30 };
    let (m_sc, p50_sc, p95_sc) = common::time(2, iters, || {
        std::hint::black_box(sim_scalar(&q, &rows, a, n, dim));
    });
    let (m_sd, p50_sd, p95_sd) = common::time(2, iters, || {
        std::hint::black_box(refc.run("sim_bench", &sim_inputs).unwrap());
    });
    let sim_speedup = m_sc as f64 / m_sd.max(1) as f64;
    println!(
        "sim ({a}×{n}×{dim}): scalar mean {} vs simd mean {} (×{sim_speedup:.2})",
        common::fmt_ns(m_sc),
        common::fmt_ns(m_sd)
    );
    kernels.push(("sim_scalar", entry(m_sc, p50_sc, p95_sc)));
    kernels.push(("sim_simd", entry(m_sd, p50_sd, p95_sd)));

    // proj: same synthetic weights + real tokenizer sparsity for both
    // legs; only the inner accumulation differs.
    let b = if smoke { 2 } else { 4 };
    let mut feats = vec![0.0f32; b * tokenizer::VOCAB];
    for (r, row) in feats.chunks_exact_mut(tokenizer::VOCAB).enumerate() {
        let text = "edge retrieval augments generation with online indexing "
            .repeat(3 + r);
        tokenizer::features_into(&text, row);
    }
    let w: Vec<f32> = (0..tokenizer::VOCAB * dim).map(|_| rng.normal() as f32).collect();
    let bias: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
    let iters = if smoke { 50 } else { 400 };
    let (m_sc, p50_sc, p95_sc) = common::time(iters / 10, iters, || {
        std::hint::black_box(proj_rows(&feats, (b, tokenizer::VOCAB, dim), &w, &bias, false));
    });
    let (m_sd, p50_sd, p95_sd) = common::time(iters / 10, iters, || {
        std::hint::black_box(proj_rows(&feats, (b, tokenizer::VOCAB, dim), &w, &bias, true));
    });
    let proj_speedup = m_sc as f64 / m_sd.max(1) as f64;
    println!(
        "proj ({b}×{}×{dim} sparse axpy): scalar mean {} vs simd mean {} (×{proj_speedup:.2})",
        tokenizer::VOCAB,
        common::fmt_ns(m_sc),
        common::fmt_ns(m_sd)
    );
    kernels.push(("proj_scalar", entry(m_sc, p50_sc, p95_sc)));
    kernels.push(("proj_simd", entry(m_sd, p50_sd, p95_sd)));

    common::bench_record("backend", json::Value::str(ctx.builder.compute.backend_name()));
    common::bench_record(
        "micro_hotpath",
        json::Value::object(vec![
            (
                "kernels",
                json::Value::Object(
                    kernels.into_iter().map(|(k, v)| (k.to_string(), v)).collect(),
                ),
            ),
            (
                "speedup",
                json::Value::object(vec![
                    ("dot", dot_speedup.into()),
                    ("sim", sim_speedup.into()),
                    ("proj", proj_speedup.into()),
                ]),
            ),
        ]),
    );
}
