//! L3 micro benches: wall-clock cost of the coordinator hot paths that sit
//! in front of every PJRT call — cache access/insert, top-k selection,
//! tokenizer featurization, centroid-probe masking, memory-model touch,
//! JSON protocol encode/decode. These are the perf-pass targets: the
//! coordinator must be invisible next to the modeled device latencies
//! (§Perf in EXPERIMENTS.md).

mod common;

use edgerag::cache::CostAwareCache;
use edgerag::data::Rng;
use edgerag::embedding::tokenizer;
use edgerag::json;
use edgerag::storage::{MemoryModel, Region};
use edgerag::vecmath::{self, EmbeddingMatrix};
use std::sync::Arc;

fn emb(rows: usize, dim: usize) -> Arc<EmbeddingMatrix> {
    let mut rng = Rng::new(7);
    let mut m = EmbeddingMatrix::new(dim);
    for _ in 0..rows {
        let row: Vec<f32> = (0..dim).map(|_| rng.normal() as f32).collect();
        m.push(&row);
    }
    Arc::new(m)
}

fn main() {
    println!("== L3 micro hot paths (wall clock, this testbed) ==");

    // 1. cost-aware cache access (hit) + decay sweep at realistic size
    let mut cache = CostAwareCache::new(64 << 20, 0.9);
    for c in 0..200u32 {
        cache.insert(c, emb(64, 256), 100.0 + c as f64);
    }
    let (mean, p50, p95) = common::time(100, 3000, || {
        std::hint::black_box(cache.access(97));
    });
    println!(
        "cache access (200 entries, hit + decay): mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 2. cache insert with eviction pressure
    let mut cache2 = CostAwareCache::new(4 << 20, 0.9);
    let block = emb(64, 256);
    let mut id = 0u32;
    let (mean, p50, p95) = common::time(50, 1000, || {
        cache2.insert(id, block.clone(), 50.0);
        id += 1;
    });
    println!(
        "cache insert+evict (4 MiB cap): mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 3. top-k over a 4096-score slab (the post-kernel selection)
    let mut rng = Rng::new(3);
    let scores: Vec<f32> = (0..4096).map(|_| rng.normal() as f32).collect();
    let (mean, p50, p95) = common::time(100, 5000, || {
        std::hint::black_box(vecmath::top_k(&scores, 4096, 5));
    });
    println!(
        "top-k(5) of 4096 scores: mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 4. tokenizer featurization of a 256-char chunk
    let text = "the quick brown fox jumps over the lazy dog ".repeat(6);
    let mut buf = vec![0.0f32; tokenizer::VOCAB];
    let (mean, p50, p95) = common::time(100, 5000, || {
        tokenizer::features_into(&text, &mut buf);
    });
    println!(
        "tokenize+featurize 256-char chunk: mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 5. memory-model touch (hit path)
    let mut mm = MemoryModel::new(1 << 30);
    for c in 0..500u32 {
        mm.touch(Region::Cluster(c), 64 << 10);
    }
    let (mean, p50, p95) = common::time(100, 5000, || {
        std::hint::black_box(mm.touch(Region::Cluster(250), 64 << 10));
    });
    println!(
        "memory-model touch (hit, 500 regions): mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 6. server JSON round-trip encode+decode of a query response
    let resp = json::Value::object(vec![
        ("hits", json::Value::array((0..5).map(|i| {
            json::Value::object(vec![("chunk", (i as u64).into()), ("score", 0.73.into())])
        }))),
        ("retrieval_ms", 123.456.into()),
        ("ttft_ms", 456.789.into()),
    ]);
    let (mean, p50, p95) = common::time(100, 5000, || {
        let s = resp.to_string();
        std::hint::black_box(json::parse(&s).unwrap());
    });
    println!(
        "JSON response encode+parse: mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );

    // 7. end-to-end coordinator overhead: one full pipeline.handle minus
    //    the PJRT time is hard to isolate; instead report handle() wall
    //    time on the tiny dataset as the upper bound.
    let ctx = common::ctx();
    let built = ctx.build("tiny").expect("build tiny");
    let pipeline = ctx
        .builder
        .pipeline(&built, edgerag::config::IndexKind::EdgeRag)
        .unwrap();
    let queries: Vec<String> = built
        .workload
        .queries
        .iter()
        .take(16)
        .map(|q| q.text.clone())
        .collect();
    let mut qi = 0;
    let (mean, p50, p95) = common::time(4, 64, || {
        let q = &queries[qi % queries.len()];
        qi += 1;
        std::hint::black_box(pipeline.handle(q).unwrap());
    });
    println!(
        "pipeline.handle (tiny, incl. PJRT): mean {} p50 {} p95 {}",
        common::fmt_ns(mean),
        common::fmt_ns(p50),
        common::fmt_ns(p95)
    );
}
