//! Shared scaffolding for the figure benches (criterion is not available
//! in this environment's crate cache, so benches are plain `harness =
//! false` binaries over the experiment harness, plus a small timing
//! utility for the micro benches).

use edgerag::config::DeviceProfile;
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::eval::experiments::{ExperimentCtx, DEFAULT_QUERY_LIMIT};
use edgerag::runtime::ComputeHandle;
use edgerag::testutil::artifacts_dir;

/// Build the default experiment context; `--full` on the bench command
/// line lifts the query budget, `--limit N` overrides it.
pub fn ctx() -> ExperimentCtx {
    let args: Vec<String> = std::env::args().collect();
    let full = args.iter().any(|a| a == "--full");
    let limit = args
        .iter()
        .position(|a| a == "--limit")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok());
    let compute = ComputeHandle::start(&artifacts_dir()).expect("run `make artifacts` first");
    let builder = SystemBuilder::new(compute, DeviceProfile::jetson_orin_nano());
    ExperimentCtx {
        builder,
        query_limit: if full { None } else { Some(limit.unwrap_or(DEFAULT_QUERY_LIMIT)) },
    }
}

/// Measure a closure's wall time over `iters` runs after `warmup` runs;
/// returns (mean, p50, p95) in nanoseconds.
#[allow(dead_code)]
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (u64, u64, u64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<u64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = std::time::Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<u64>() / iters as u64;
    (mean, samples[iters / 2], samples[iters * 95 / 100])
}

#[allow(dead_code)]
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}
