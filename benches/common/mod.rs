//! Shared scaffolding for the figure benches (criterion is not available
//! in this environment's crate cache, so benches are plain `harness =
//! false` binaries over the experiment harness, plus a small timing
//! utility for the micro benches).

use edgerag::config::DeviceProfile;
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::eval::experiments::{ExperimentCtx, DEFAULT_QUERY_LIMIT};
use edgerag::runtime::ComputeHandle;
use edgerag::testutil::artifacts_dir;

/// Build the default experiment context; `--full` on the bench command
/// line lifts the query budget, `--limit N` overrides it.
pub fn ctx() -> ExperimentCtx {
    let args: Vec<String> = std::env::args().collect();
    let full = args.iter().any(|a| a == "--full");
    let limit = args
        .iter()
        .position(|a| a == "--limit")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok());
    let compute = ComputeHandle::start(&artifacts_dir()).expect("run `make artifacts` first");
    let builder = SystemBuilder::new(compute, DeviceProfile::jetson_orin_nano());
    ExperimentCtx {
        builder,
        query_limit: if full { None } else { Some(limit.unwrap_or(DEFAULT_QUERY_LIMIT)) },
    }
}

/// Measure a closure's wall time over `iters` runs after `warmup` runs;
/// returns (mean, p50, p95) in nanoseconds.
#[allow(dead_code)]
pub fn time<F: FnMut()>(warmup: usize, iters: usize, mut f: F) -> (u64, u64, u64) {
    for _ in 0..warmup {
        f();
    }
    let mut samples: Vec<u64> = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = std::time::Instant::now();
        f();
        samples.push(t.elapsed().as_nanos() as u64);
    }
    samples.sort_unstable();
    let mean = samples.iter().sum::<u64>() / iters as u64;
    (mean, samples[iters / 2], samples[iters * 95 / 100])
}

/// True when the bench was invoked with `--smoke`: CI mode, shrink
/// iteration counts so the whole bench finishes in seconds while still
/// exercising every code path and emitting a schema-complete trajectory.
#[allow(dead_code)]
pub fn smoke() -> bool {
    std::env::args().any(|a| a == "--smoke")
}

/// Where the recorded trajectory goes: `$EDGERAG_BENCH_OUT` if set, else
/// `BENCH_9.json` in the current directory.
#[allow(dead_code)]
pub fn bench_out_path() -> std::path::PathBuf {
    std::env::var("EDGERAG_BENCH_OUT")
        .map(Into::into)
        .unwrap_or_else(|_| "BENCH_9.json".into())
}

/// Record one section of the machine-readable bench trajectory
/// (`edgerag-bench/v1`, see README). Read-modify-write so the two bench
/// binaries compose into a single `BENCH_9.json`: each call replaces its
/// own section and leaves the others intact. Validate the result with
/// `edgerag bench-validate`.
#[allow(dead_code)]
pub fn bench_record(section: &str, value: edgerag::json::Value) {
    use edgerag::json::Value;
    let path = bench_out_path();
    let root = std::fs::read_to_string(&path)
        .ok()
        .and_then(|s| edgerag::json::parse(&s).ok())
        .unwrap_or(Value::Null);
    let mut map = match root {
        Value::Object(m) => m,
        _ => Default::default(),
    };
    map.insert("schema".into(), Value::str("edgerag-bench/v1"));
    map.insert("pr".into(), Value::num(9.0));
    map.insert(section.into(), value);
    std::fs::write(&path, Value::Object(map).pretty()).expect("write bench trajectory");
    eprintln!("[bench] recorded section `{section}` -> {}", path.display());
}

/// Nearest-rank percentile over an already-sorted nanosecond slice.
#[allow(dead_code)]
pub fn pctl_ns(sorted: &[u64], p: f64) -> u64 {
    if sorted.is_empty() {
        return 0;
    }
    let rank = ((p / 100.0) * sorted.len() as f64).ceil().max(1.0) as usize;
    sorted[rank.min(sorted.len()) - 1]
}

#[allow(dead_code)]
pub fn fmt_ns(ns: u64) -> String {
    if ns >= 1_000_000 {
        format!("{:.2}ms", ns as f64 / 1e6)
    } else if ns >= 1_000 {
        format!("{:.1}µs", ns as f64 / 1e3)
    } else {
        format!("{ns}ns")
    }
}
