//! Bench E5 — paper Fig. 7: retrieval latency and cache hit rate across
//! pinned Minimum Latency Caching Thresholds (fever-like profile), plus
//! the adaptive controller's operating point.

mod common;

fn main() -> anyhow::Result<()> {
    let ctx = common::ctx();
    edgerag::eval::experiments::fig7(&ctx, "fever")?;
    Ok(())
}
