//! Concurrent-serving throughput bench: the same query stream driven
//! through one shared `Engine` by 1, 2 and 4 client threads.
//!
//!     cargo bench --bench throughput_scaling [-- --limit N]
//!
//! Before the read-parallel refactor every request serialized on a
//! `Mutex<RagPipeline>`, so thread count could not change throughput.
//! Now searches take only a read lease, so queries-per-second must scale
//! >1× from 1 → 4 threads whenever compute executes caller-side (the
//! reference backend, or any future multi-client PJRT setup). The
//! modeled per-query device time (`wall_us` on the wire = `out.wall`
//! here) stays flat — parallelism adds throughput, not per-query work.

mod common;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};

use edgerag::config::IndexKind;
use edgerag::coordinator::Engine;

/// Drive `passes` full passes over `queries` from `threads` workers
/// against the shared engine. Returns (elapsed seconds, served queries,
/// summed per-query coordinator wall time in µs).
fn drive(engine: &Engine, queries: &[String], threads: usize, passes: usize) -> (f64, u64, u64) {
    let next = AtomicUsize::new(0);
    let wall_us = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    let total = queries.len() * passes;
    let start = std::time::Instant::now();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let wall_us = &wall_us;
            let served = &served;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let out = engine.handle(&queries[i % queries.len()]).unwrap();
                wall_us.fetch_add(out.wall.as_micros() as u64, Ordering::Relaxed);
                served.fetch_add(1, Ordering::Relaxed);
            });
        }
    });
    (
        start.elapsed().as_secs_f64(),
        served.load(Ordering::Relaxed),
        wall_us.load(Ordering::Relaxed),
    )
}

fn main() {
    let ctx = common::ctx();
    let built = ctx.build("tiny").expect("build tiny");
    let engine = ctx
        .builder
        .pipeline(&built, IndexKind::EdgeRag)
        .expect("build engine");
    println!(
        "== throughput scaling: shared engine, {} compute backend ==",
        ctx.builder.compute.backend_name()
    );

    let queries: Vec<String> = built
        .workload
        .queries
        .iter()
        .take(32)
        .map(|q| q.text.clone())
        .collect();

    // Warm once so every thread count measures the same steady state
    // (cache populated, residency settled).
    for q in &queries {
        engine.handle(q).unwrap();
    }

    let passes = 8;
    let mut qps_1 = 0.0;
    for threads in [1usize, 2, 4] {
        let (secs, served, wall_us) = drive(&engine, &queries, threads, passes);
        let qps = served as f64 / secs;
        if threads == 1 {
            qps_1 = qps;
        }
        println!(
            "{threads} client thread(s): {served} queries in {secs:.3}s → {qps:8.1} q/s \
             (speedup ×{:.2}, mean wall {}µs/query)",
            qps / qps_1,
            wall_us / served.max(1)
        );
    }
    println!(
        "\nacceptance: >1× throughput scaling from 1→4 threads on the wall_us path \
         (read-parallel searches; no whole-pipeline mutex)"
    );
}
