//! Concurrent-serving throughput bench: the same query stream driven
//! through one shared `Engine` by 1, 2 and 4 client threads, then a
//! shard-count sweep (`shards` ∈ {1, 4, 8}) at a fixed client count,
//! then a cross-query batching sweep (scheduler off vs on) at ≥8
//! clients, then an executor-pool sweep (`--compute-threads` ∈
//! {1, 2, 4}), then a connection-scaling sweep over real TCP (the
//! thread-per-connection front end vs the event-driven reactor at
//! 1/8/64 persistent connections), then a tracing sweep (the
//! query-scoped tracing plane dark vs armed — overhead must stay
//! within a few percent), then a resharding sweep (one live engine
//! driven through grow/shrink rounds, measuring serving at each live
//! shard count), then a skewed-placement rebalance sweep (one shard
//! seeded with every cluster; spread before/after bounded rounds).
//!
//!     cargo bench --bench throughput_scaling [-- --limit N | --smoke]
//!
//! Each sweep records qps + per-request p50/p95/p99 wall latency into
//! the machine-readable trajectory (`BENCH_10.json`, section
//! `throughput_scaling`) — validate with `edgerag bench-validate`.
//!
//! Before the read-parallel refactor every request serialized on a
//! `Mutex<RagPipeline>`, so thread count could not change throughput.
//! Now searches take only a read lease, so queries-per-second must scale
//! >1× from 1 → 4 threads whenever compute executes caller-side (the
//! reference backend, or any future multi-client PJRT setup).
//!
//! The shard sweep measures the `ShardedEdgeIndex`: with `shards = N`
//! each query's probed clusters fan out across per-shard cluster walks
//! on the shard pool, and commits take per-shard locks instead of one
//! global cache/threshold lock. Gains over `shards = 1` at the *same*
//! client count come from intra-query parallelism plus commit-lock
//! decontention, so they grow with spare cores; on a core-starved host
//! the sweep primarily shows that sharding adds no meaningful overhead
//! while the combined `shards = 4 / 4 clients` configuration clears
//! ≥1.5× the serial (`shards = 1 / 1 client`) baseline. The modeled
//! per-query device time (`wall_us` on the wire = `out.wall` here)
//! stays flat — parallelism adds throughput, not per-query work.

mod common;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::{Arc, Mutex};

use edgerag::config::IndexKind;
use edgerag::coordinator::{Engine, QueryOutcome};
use edgerag::json;

/// One sweep point's measurements: elapsed wall clock, served queries,
/// summed modeled per-query wall µs, and the sorted per-request
/// wall-clock latencies (real time, this testbed) for percentiles.
struct Driven {
    secs: f64,
    served: u64,
    wall_us: u64,
    lat_ns: Vec<u64>,
}

impl Driven {
    fn qps(&self) -> f64 {
        self.served as f64 / self.secs
    }

    fn mean_wall_us(&self) -> u64 {
        self.wall_us / self.served.max(1)
    }

    fn p_us(&self, p: f64) -> f64 {
        common::pctl_ns(&self.lat_ns, p) as f64 / 1e3
    }

    /// A trajectory row: `extra` labels (shards/clients/...) plus the
    /// qps + p50/p95/p99 every row of the schema carries.
    fn row(&self, extra: Vec<(&str, json::Value)>) -> json::Value {
        let mut pairs = extra;
        pairs.push(("qps", self.qps().into()));
        pairs.push(("p50_us", self.p_us(50.0).into()));
        pairs.push(("p95_us", self.p_us(95.0).into()));
        pairs.push(("p99_us", self.p_us(99.0).into()));
        json::Value::object(pairs)
    }
}

/// Drive `passes` full passes over `queries` from `threads` workers
/// through an arbitrary query handler.
fn drive_with<F>(handle: F, queries: &[String], threads: usize, passes: usize) -> Driven
where
    F: Fn(&str) -> anyhow::Result<QueryOutcome> + Sync,
{
    let next = AtomicUsize::new(0);
    let wall_us = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    let lat_ns: Mutex<Vec<u64>> = Mutex::new(Vec::with_capacity(queries.len() * passes));
    let total = queries.len() * passes;
    let start = std::time::Instant::now();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let wall_us = &wall_us;
            let served = &served;
            let lat_ns = &lat_ns;
            let handle = &handle;
            s.spawn(move || {
                let mut local = Vec::with_capacity(total / threads + 1);
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let t = std::time::Instant::now();
                    let out = handle(&queries[i % queries.len()]).unwrap();
                    local.push(t.elapsed().as_nanos() as u64);
                    wall_us.fetch_add(out.wall.as_micros() as u64, Ordering::Relaxed);
                    served.fetch_add(1, Ordering::Relaxed);
                }
                lat_ns.lock().unwrap().extend_from_slice(&local);
            });
        }
    });
    let secs = start.elapsed().as_secs_f64();
    let mut lat_ns = lat_ns.into_inner().unwrap();
    lat_ns.sort_unstable();
    Driven {
        secs,
        served: served.load(Ordering::Relaxed),
        wall_us: wall_us.load(Ordering::Relaxed),
        lat_ns,
    }
}

/// Drive against the shared engine directly (the unbatched path).
fn drive(engine: &Engine, queries: &[String], threads: usize, passes: usize) -> Driven {
    drive_with(|q| engine.handle(q), queries, threads, passes)
}

/// Drive a running TCP server from `conns` persistent keep-alive
/// connections, one blocking client thread each, sharing a fixed total
/// query budget. Real sockets, real line protocol — this is the sweep
/// the two front ends (thread-per-connection vs reactor) are compared
/// on.
fn drive_tcp(addr: &std::net::SocketAddr, queries: &[String], conns: usize, total: usize) -> Driven {
    let next = AtomicUsize::new(0);
    let served = AtomicU64::new(0);
    let lat_ns: Mutex<Vec<u64>> = Mutex::new(Vec::with_capacity(total));
    let start = std::time::Instant::now();
    std::thread::scope(|s| {
        for _ in 0..conns {
            let next = &next;
            let served = &served;
            let lat_ns = &lat_ns;
            s.spawn(move || {
                let mut c = edgerag::server::Client::connect(&addr.to_string())
                    .expect("connect bench client");
                let mut local = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= total {
                        break;
                    }
                    let t = std::time::Instant::now();
                    let resp = c.query(&queries[i % queries.len()]).unwrap();
                    assert!(resp.get("hits").is_some(), "query failed over the wire: {resp}");
                    local.push(t.elapsed().as_nanos() as u64);
                    served.fetch_add(1, Ordering::Relaxed);
                }
                lat_ns.lock().unwrap().extend_from_slice(&local);
            });
        }
    });
    let secs = start.elapsed().as_secs_f64();
    let mut lat_ns = lat_ns.into_inner().unwrap();
    lat_ns.sort_unstable();
    Driven {
        secs,
        served: served.load(Ordering::Relaxed),
        wall_us: 0, // modeled device time is not on the wire per-hit here
        lat_ns,
    }
}

fn main() {
    let ctx = common::ctx();
    let built = ctx.build("tiny").expect("build tiny");
    let engine = ctx
        .builder
        .pipeline(&built, IndexKind::EdgeRag)
        .expect("build engine");
    println!(
        "== throughput scaling: shared engine, {} compute backend ==",
        ctx.builder.compute.backend_name()
    );

    let queries: Vec<String> = built
        .workload
        .queries
        .iter()
        .take(if common::smoke() { 8 } else { 32 })
        .map(|q| q.text.clone())
        .collect();

    // Warm once so every thread count measures the same steady state
    // (cache populated, residency settled).
    for q in &queries {
        engine.handle(q).unwrap();
    }

    let passes = if common::smoke() { 2 } else { 8 };
    // qps at shards=1 / 1 client — the serial baseline both sections
    // normalize against.
    let mut qps_serial = 0.0;
    for threads in [1usize, 2, 4] {
        let d = drive(&engine, &queries, threads, passes);
        if threads == 1 {
            qps_serial = d.qps();
        }
        println!(
            "{threads} client thread(s): {} queries in {:.3}s → {:8.1} q/s \
             (speedup ×{:.2}, mean wall {}µs/query)",
            d.served,
            d.secs,
            d.qps(),
            d.qps() / qps_serial,
            d.mean_wall_us()
        );
    }

    // ---- shard sweep: fixed client count, shards ∈ {1, 4, 8} ----
    let clients = 4;
    println!("\n== shard sweep: {clients} client threads ==");
    let mut qps_one_shard = 0.0;
    let mut qps_best = 0.0;
    let mut shard_rows: Vec<json::Value> = Vec::new();
    for shards in [1usize, 4, 8] {
        let mut b = ctx.builder.clone();
        b.retrieval.shards = shards;
        let engine = b
            .pipeline(&built, IndexKind::EdgeRag)
            .expect("build sharded engine");
        for q in &queries {
            engine.handle(q).unwrap(); // warm each engine identically
        }
        let d = drive(&engine, &queries, clients, passes);
        if shards == 1 {
            qps_one_shard = d.qps();
        }
        qps_best = qps_best.max(d.qps());
        println!(
            "shards={shards}: {} queries in {:.3}s → {:8.1} q/s \
             (vs shards=1 ×{:.2}, vs serial ×{:.2}, mean wall {}µs/query, \
             p50/p95/p99 {:.0}/{:.0}/{:.0}µs)",
            d.served,
            d.secs,
            d.qps(),
            d.qps() / qps_one_shard,
            d.qps() / qps_serial,
            d.mean_wall_us(),
            d.p_us(50.0),
            d.p_us(95.0),
            d.p_us(99.0)
        );
        shard_rows.push(d.row(vec![
            ("shards", shards.into()),
            ("clients", clients.into()),
        ]));
    }
    println!(
        "\nacceptance: shards=1 is bit-identical to the unsharded EdgeIndex \
         (tests/sharded_equivalence.rs); best sharded throughput ×{:.2} \
         over the serial baseline (target ≥1.5×, core-count permitting)",
        qps_best / qps_serial
    );

    // ---- batching sweep: ≥8 clients, cross-query scheduler off vs on ----
    // Under 8-way concurrency every query used to issue batch-1 kernel
    // calls; the scheduler coalesces concurrent embed/probe work into
    // fused `proj_{B}` / `sim_{A}x{N}` calls (bit-identical results —
    // tests/sched_equivalence.rs). Gains grow when kernel dispatch
    // overhead dominates (the PJRT executor) or clients oversubscribe
    // cores; the reference backend on a many-core host mainly shows the
    // occupancy the fused calls reach.
    let clients = 8;
    println!("\n== batching sweep: {clients} client threads ==");
    let mut qps_off = 0.0;
    let mut qps_on = 0.0;
    let mut batching_rows: Vec<json::Value> = Vec::new();
    for batching in [false, true] {
        let engine = Arc::new(
            ctx.builder
                .pipeline(&built, IndexKind::EdgeRag)
                .expect("build engine"),
        );
        for q in &queries {
            engine.handle(q).unwrap(); // warm identically
        }
        if !batching {
            let d = drive(&engine, &queries, clients, passes);
            qps_off = d.qps();
            println!(
                "batching off: {} queries in {:.3}s → {qps_off:8.1} q/s \
                 (mean wall {}µs/query)",
                d.served,
                d.secs,
                d.mean_wall_us()
            );
            batching_rows.push(d.row(vec![
                ("batching", false.into()),
                ("clients", clients.into()),
            ]));
        } else {
            let sched = ctx.builder.scheduler(engine.clone());
            let d = drive_with(|q| sched.handle(q), &queries, clients, passes);
            qps_on = d.qps();
            let s = sched.stats();
            println!(
                "batching on:  {} queries in {:.3}s → {qps_on:8.1} q/s \
                 (vs off ×{:.2}, mean wall {}µs/query)",
                d.served,
                d.secs,
                qps_on / qps_off,
                d.mean_wall_us()
            );
            println!(
                "              embed occupancy {:.1} ({} batches, {} full-width, {} window-expired); \
                 probe occupancy {:.1} ({} batches); bypassed {}",
                s.embed.occupancy(),
                s.embed.batches,
                s.embed.full_width,
                s.embed.window_expired,
                s.probe.occupancy(),
                s.probe.batches,
                s.bypassed,
            );
            batching_rows.push(d.row(vec![
                ("batching", true.into()),
                ("clients", clients.into()),
            ]));
        }
    }
    println!(
        "acceptance: batching on ×{:.2} vs off at {clients} clients \
         (bit-identical results; fused-call occupancy above shows the \
         dispatch amortization the compiled backend banks on)",
        qps_on / qps_off
    );

    // ---- executor-pool sweep: compute threads ∈ {1, 2, 4} ----
    // Same engine config, but the compute service behind `ComputeHandle`
    // is restarted with an explicit pool width (the `--compute-threads`
    // serve knob). With the PJRT backend each width is a real executor
    // pool (one `Runtime` per thread, shared job queue); the reference
    // fallback executes caller-side (`pool 0` below) and the sweep then
    // records that dispatch adds no overhead as the knob moves.
    let clients = 4;
    println!("\n== executor-pool sweep: {clients} client threads ==");
    let mut pool_rows: Vec<json::Value> = Vec::new();
    for threads in [1usize, 2, 4] {
        let compute = edgerag::runtime::ComputeHandle::start_with_threads(
            &edgerag::testutil::artifacts_dir(),
            threads,
        )
        .expect("restart compute service");
        let pool = compute.executor_threads();
        let mut b = ctx.builder.clone();
        b.compute = compute;
        let engine = b
            .pipeline(&built, IndexKind::EdgeRag)
            .expect("build engine on fresh pool");
        for q in &queries {
            engine.handle(q).unwrap(); // warm identically
        }
        let d = drive(&engine, &queries, clients, passes);
        println!(
            "compute-threads={threads} (pool {pool}, {} backend): {} queries \
             in {:.3}s → {:8.1} q/s (mean wall {}µs/query)",
            b.compute.backend_name(),
            d.served,
            d.secs,
            d.qps(),
            d.mean_wall_us()
        );
        pool_rows.push(d.row(vec![
            ("compute_threads", threads.into()),
            ("pool_threads", pool.into()),
            ("clients", clients.into()),
        ]));
    }

    // ---- connection sweep: real TCP, thread-per-connection vs reactor ----
    // Identical engine configuration behind both front ends, so the
    // delta is what the serving layer itself adds. The threaded
    // baseline parks one handler thread (plus a blocking reply channel
    // per request) on every connection; the reactor multiplexes every
    // socket onto one poll loop and a fixed worker pool — q/s should
    // hold or improve as connections grow while its thread count stays
    // flat.
    let conn_counts: &[usize] = if common::smoke() { &[1, 8] } else { &[1, 8, 64] };
    let total = queries.len() * passes;
    println!("\n== connection sweep: real TCP, {total} queries per point ==");
    let mut conn_rows: Vec<json::Value> = Vec::new();
    for mode in ["threaded", "reactor"] {
        let mut qps_one_conn = 0.0;
        for &conns in conn_counts {
            let engine = ctx
                .builder
                .pipeline(&built, IndexKind::EdgeRag)
                .expect("build engine");
            for q in &queries {
                engine.handle(q).unwrap(); // warm identically
            }
            let server = edgerag::server::Server::bind_with_workers(
                "127.0.0.1:0",
                engine,
                ctx.builder.embedder(),
                4,
            )
            .expect("bind bench server");
            let addr = server.local_addr().expect("bench server addr");
            let reactor = mode == "reactor";
            let running = std::thread::spawn(move || {
                if reactor {
                    server.run()
                } else {
                    server.run_threaded()
                }
            });
            let d = drive_tcp(&addr, &queries, conns, total);
            let mut shut = edgerag::server::Client::connect(&addr.to_string())
                .expect("connect for shutdown");
            shut.call(&json::Value::object(vec![("op", json::Value::str("shutdown"))]))
                .expect("shutdown op");
            running.join().expect("server thread").expect("server run");
            if conns == conn_counts[0] {
                qps_one_conn = d.qps();
            }
            println!(
                "{mode:8} conns={conns:3}: {} queries in {:.3}s → {:8.1} q/s \
                 (vs {} conn(s) ×{:.2}, p50/p95/p99 {:.0}/{:.0}/{:.0}µs)",
                d.served,
                d.secs,
                d.qps(),
                conn_counts[0],
                d.qps() / qps_one_conn,
                d.p_us(50.0),
                d.p_us(95.0),
                d.p_us(99.0)
            );
            conn_rows.push(d.row(vec![
                ("mode", json::Value::str(mode)),
                ("connections", conns.into()),
            ]));
        }
    }
    println!(
        "acceptance: reactor q/s holds as connections grow while idle \
         connections cost a slab slot + buffers instead of a parked \
         handler thread (tests/server_integration.rs pins the no-thread \
         property at 200 idle connections)"
    );

    // ---- tracing sweep: the query-scoped tracing plane, dark vs armed ----
    // Runs LAST among the recorded sweeps: the first `Tracer::new` arms
    // the process-global enable flag permanently, so the off row (and
    // every sweep above) measures the true dark path — one relaxed
    // atomic load per record site, zero allocation. The on row gives
    // every query an active trace, records the full span tree, and
    // (threshold 0) pushes every trace through the slow ring too — the
    // worst case. Acceptance: within a few percent of the dark row.
    let clients = 4;
    println!("\n== tracing sweep: {clients} client threads ==");
    let mut qps_dark = 0.0;
    let mut tracing_rows: Vec<json::Value> = Vec::new();
    for tracing in [false, true] {
        let engine = Arc::new(
            ctx.builder
                .pipeline(&built, IndexKind::EdgeRag)
                .expect("build engine"),
        );
        for q in &queries {
            engine.handle(q).unwrap(); // warm identically
        }
        if !tracing {
            let d = drive(&engine, &queries, clients, passes);
            qps_dark = d.qps();
            println!(
                "tracing off: {} queries in {:.3}s → {qps_dark:8.1} q/s \
                 (mean wall {}µs/query)",
                d.served,
                d.secs,
                d.mean_wall_us()
            );
            tracing_rows.push(d.row(vec![
                ("tracing", false.into()),
                ("clients", clients.into()),
            ]));
        } else {
            let tracer = edgerag::trace::Tracer::new(0);
            let d = drive_with(
                |q| {
                    let guard = tracer.begin("query", std::time::Instant::now());
                    let out = engine.handle(q);
                    let _ = guard.finish();
                    out
                },
                &queries,
                clients,
                passes,
            );
            let ts = tracer.stats();
            println!(
                "tracing on:  {} queries in {:.3}s → {:8.1} q/s \
                 (vs off ×{:.2}, mean wall {}µs/query; {} traces captured, \
                 {} through the slow ring)",
                d.served,
                d.secs,
                d.qps(),
                d.qps() / qps_dark,
                d.mean_wall_us(),
                ts.finished,
                ts.slow
            );
            println!(
                "acceptance: tracing-on throughput ×{:.2} of dark \
                 (target ≥0.95 — span capture must stay observational)",
                d.qps() / qps_dark
            );
            tracing_rows.push(d.row(vec![
                ("tracing", true.into()),
                ("clients", clients.into()),
            ]));
        }
    }

    // ---- resharding sweep: one live engine, elastic shard count ----
    // The same engine (and the same warmed cache state) is resharded
    // through 2 → 4 → 8 → 1 → 2 online — grows append empty shards the
    // heat-aware rebalancer then fills, shrinks drain-then-retire — and
    // serving is measured at each live count. Results stay bit-identical
    // to the single-shard oracle through every topology swap
    // (rust/tests/rebalance_churn.rs pins that); this sweep reports what
    // the elasticity costs/buys in throughput.
    let clients = 4;
    println!("\n== resharding sweep: live engine, {clients} client threads ==");
    let mut reshard_rows: Vec<json::Value> = Vec::new();
    {
        let mut b = ctx.builder.clone();
        b.retrieval.shards = 2;
        let engine = b
            .pipeline(&built, IndexKind::EdgeRag)
            .expect("build sharded engine");
        for q in &queries {
            engine.handle(q).unwrap(); // warm once; state persists across swaps
        }
        for target in [2usize, 4, 8, 1, 2] {
            let (from, migrated) = {
                let index = engine.index();
                let sharded = index
                    .as_any()
                    .downcast_ref::<edgerag::index::ShardedEdgeIndex>()
                    .expect("shards=2 builds the sharded index");
                let r = sharded.reshard(target).expect("reshard");
                sharded.rebalance().expect("fill grown shards");
                (r.from, r.migrated)
            };
            let d = drive(&engine, &queries, clients, passes);
            println!(
                "shards {from}→{target}: {} drained; {} queries in {:.3}s → {:8.1} q/s \
                 (mean wall {}µs/query, p50/p95/p99 {:.0}/{:.0}/{:.0}µs)",
                migrated,
                d.served,
                d.secs,
                d.qps(),
                d.mean_wall_us(),
                d.p_us(50.0),
                d.p_us(95.0),
                d.p_us(99.0)
            );
            reshard_rows.push(d.row(vec![
                ("shards", target.into()),
                ("resharded_from", from.into()),
                ("migrated", migrated.into()),
                ("clients", clients.into()),
            ]));
        }
        println!(
            "acceptance: every grow/shrink lands under live traffic with \
             bit-identical results; q/s at a given live count tracks the \
             static shard sweep above"
        );
    }

    common::bench_record("backend", json::Value::str(ctx.builder.compute.backend_name()));
    common::bench_record(
        "throughput_scaling",
        json::Value::object(vec![
            ("shard_sweep", json::Value::array(shard_rows)),
            ("batching_sweep", json::Value::array(batching_rows)),
            ("executor_pool", json::Value::array(pool_rows)),
            ("connection_sweep", json::Value::array(conn_rows)),
            ("tracing_sweep", json::Value::array(tracing_rows)),
            ("resharding_sweep", json::Value::array(reshard_rows)),
        ]),
    );

    // ---- rebalance sweep: skewed placement, live migration, spread ----
    // Worst-case drift: every cluster on shard 0 (what round-robin decay
    // looks like in the limit). Bounded rebalance rounds must pull the
    // per-shard load spread down while queries keep serving identical
    // results (rust/tests/rebalance_churn.rs pins the bit-identity; this
    // sweep reports the load numbers).
    let clients = 4;
    println!("\n== rebalance sweep: 4 shards, {clients} client threads ==");
    let mut b = ctx.builder.clone();
    b.retrieval.shards = 4;
    let engine = b
        .pipeline(&built, IndexKind::EdgeRag)
        .expect("build sharded engine");
    for q in &queries {
        engine.handle(q).unwrap();
    }
    {
        let index = engine.index();
        let sharded = index
            .as_any()
            .downcast_ref::<edgerag::index::ShardedEdgeIndex>()
            .expect("shards=4 builds the sharded index");
        let globals: Vec<u32> = sharded
            .cluster_loads()
            .iter()
            .flatten()
            .map(|c| c.global)
            .collect();
        for &g in &globals {
            sharded.migrate_cluster(g, 0).expect("skew migration");
        }
        let rows = |s: &edgerag::index::ShardStats| s.rows;
        let spread_before = sharded.load_spread();
        let per_shard: Vec<u64> = sharded.shard_stats().iter().map(rows).collect();
        println!("skewed:     spread {spread_before:6} rows, per-shard {per_shard:?}");

        let (mut rounds, mut migrations) = (0usize, 0usize);
        loop {
            let r = sharded.rebalance().expect("rebalance round");
            rounds += 1;
            migrations += r.migrated;
            if r.migrated == 0 || rounds >= 16 {
                break;
            }
        }
        let spread_after = sharded.load_spread();
        let per_shard: Vec<u64> = sharded.shard_stats().iter().map(rows).collect();
        println!(
            "rebalanced: spread {spread_after:6} rows, per-shard {per_shard:?} \
             ({migrations} migrations over {rounds} rounds, ≤4 per round)"
        );
        println!(
            "acceptance: post-rebalance load spread ×{:.2} of the skewed \
             spread (target ≤0.5; searches stay bit-identical to the \
             single-shard oracle throughout — rebalance_churn.rs)",
            spread_after as f64 / spread_before.max(1) as f64
        );
    }
    let d = drive(&engine, &queries, clients, passes);
    println!(
        "post-rebalance serving: {} queries in {:.3}s → {:8.1} q/s \
         (mean wall {}µs/query)",
        d.served,
        d.secs,
        d.qps(),
        d.mean_wall_us()
    );
}
