//! Concurrent-serving throughput bench: the same query stream driven
//! through one shared `Engine` by 1, 2 and 4 client threads, then a
//! shard-count sweep (`shards` ∈ {1, 2, 4}) at a fixed client count,
//! then a cross-query batching sweep (scheduler off vs on) at ≥8
//! clients, then a skewed-placement rebalance sweep (one shard seeded
//! with every cluster; spread before/after bounded rebalance rounds).
//!
//!     cargo bench --bench throughput_scaling [-- --limit N]
//!
//! Before the read-parallel refactor every request serialized on a
//! `Mutex<RagPipeline>`, so thread count could not change throughput.
//! Now searches take only a read lease, so queries-per-second must scale
//! >1× from 1 → 4 threads whenever compute executes caller-side (the
//! reference backend, or any future multi-client PJRT setup).
//!
//! The shard sweep measures the `ShardedEdgeIndex`: with `shards = N`
//! each query's probed clusters fan out across per-shard cluster walks
//! on the shard pool, and commits take per-shard locks instead of one
//! global cache/threshold lock. Gains over `shards = 1` at the *same*
//! client count come from intra-query parallelism plus commit-lock
//! decontention, so they grow with spare cores; on a core-starved host
//! the sweep primarily shows that sharding adds no meaningful overhead
//! while the combined `shards = 4 / 4 clients` configuration clears
//! ≥1.5× the serial (`shards = 1 / 1 client`) baseline. The modeled
//! per-query device time (`wall_us` on the wire = `out.wall` here)
//! stays flat — parallelism adds throughput, not per-query work.

mod common;

use std::sync::atomic::{AtomicU64, AtomicUsize, Ordering};
use std::sync::Arc;

use edgerag::config::IndexKind;
use edgerag::coordinator::{Engine, QueryOutcome};

/// Drive `passes` full passes over `queries` from `threads` workers
/// through an arbitrary query handler. Returns (elapsed seconds, served
/// queries, summed per-query coordinator wall time in µs).
fn drive_with<F>(handle: F, queries: &[String], threads: usize, passes: usize) -> (f64, u64, u64)
where
    F: Fn(&str) -> anyhow::Result<QueryOutcome> + Sync,
{
    let next = AtomicUsize::new(0);
    let wall_us = AtomicU64::new(0);
    let served = AtomicU64::new(0);
    let total = queries.len() * passes;
    let start = std::time::Instant::now();
    std::thread::scope(|s| {
        for _ in 0..threads {
            let next = &next;
            let wall_us = &wall_us;
            let served = &served;
            let handle = &handle;
            s.spawn(move || loop {
                let i = next.fetch_add(1, Ordering::Relaxed);
                if i >= total {
                    break;
                }
                let out = handle(&queries[i % queries.len()]).unwrap();
                wall_us.fetch_add(out.wall.as_micros() as u64, Ordering::Relaxed);
                served.fetch_add(1, Ordering::Relaxed);
            });
        }
    });
    (
        start.elapsed().as_secs_f64(),
        served.load(Ordering::Relaxed),
        wall_us.load(Ordering::Relaxed),
    )
}

/// Drive against the shared engine directly (the unbatched path).
fn drive(engine: &Engine, queries: &[String], threads: usize, passes: usize) -> (f64, u64, u64) {
    drive_with(|q| engine.handle(q), queries, threads, passes)
}

fn main() {
    let ctx = common::ctx();
    let built = ctx.build("tiny").expect("build tiny");
    let engine = ctx
        .builder
        .pipeline(&built, IndexKind::EdgeRag)
        .expect("build engine");
    println!(
        "== throughput scaling: shared engine, {} compute backend ==",
        ctx.builder.compute.backend_name()
    );

    let queries: Vec<String> = built
        .workload
        .queries
        .iter()
        .take(32)
        .map(|q| q.text.clone())
        .collect();

    // Warm once so every thread count measures the same steady state
    // (cache populated, residency settled).
    for q in &queries {
        engine.handle(q).unwrap();
    }

    let passes = 8;
    // qps at shards=1 / 1 client — the serial baseline both sections
    // normalize against.
    let mut qps_serial = 0.0;
    for threads in [1usize, 2, 4] {
        let (secs, served, wall_us) = drive(&engine, &queries, threads, passes);
        let qps = served as f64 / secs;
        if threads == 1 {
            qps_serial = qps;
        }
        println!(
            "{threads} client thread(s): {served} queries in {secs:.3}s → {qps:8.1} q/s \
             (speedup ×{:.2}, mean wall {}µs/query)",
            qps / qps_serial,
            wall_us / served.max(1)
        );
    }

    // ---- shard sweep: fixed client count, shards ∈ {1, 2, 4} ----
    let clients = 4;
    println!("\n== shard sweep: {clients} client threads ==");
    let mut qps_one_shard = 0.0;
    let mut qps_best = 0.0;
    for shards in [1usize, 2, 4] {
        let mut b = ctx.builder.clone();
        b.retrieval.shards = shards;
        let engine = b
            .pipeline(&built, IndexKind::EdgeRag)
            .expect("build sharded engine");
        for q in &queries {
            engine.handle(q).unwrap(); // warm each engine identically
        }
        let (secs, served, wall_us) = drive(&engine, &queries, clients, passes);
        let qps = served as f64 / secs;
        if shards == 1 {
            qps_one_shard = qps;
        }
        qps_best = qps_best.max(qps);
        println!(
            "shards={shards}: {served} queries in {secs:.3}s → {qps:8.1} q/s \
             (vs shards=1 ×{:.2}, vs serial ×{:.2}, mean wall {}µs/query)",
            qps / qps_one_shard,
            qps / qps_serial,
            wall_us / served.max(1)
        );
    }
    println!(
        "\nacceptance: shards=1 is bit-identical to the unsharded EdgeIndex \
         (tests/sharded_equivalence.rs); best sharded throughput ×{:.2} \
         over the serial baseline (target ≥1.5×, core-count permitting)",
        qps_best / qps_serial
    );

    // ---- batching sweep: ≥8 clients, cross-query scheduler off vs on ----
    // Under 8-way concurrency every query used to issue batch-1 kernel
    // calls; the scheduler coalesces concurrent embed/probe work into
    // fused `proj_{B}` / `sim_{A}x{N}` calls (bit-identical results —
    // tests/sched_equivalence.rs). Gains grow when kernel dispatch
    // overhead dominates (the PJRT executor) or clients oversubscribe
    // cores; the reference backend on a many-core host mainly shows the
    // occupancy the fused calls reach.
    let clients = 8;
    println!("\n== batching sweep: {clients} client threads ==");
    let mut qps_off = 0.0;
    let mut qps_on = 0.0;
    for batching in [false, true] {
        let engine = Arc::new(
            ctx.builder
                .pipeline(&built, IndexKind::EdgeRag)
                .expect("build engine"),
        );
        for q in &queries {
            engine.handle(q).unwrap(); // warm identically
        }
        if !batching {
            let (secs, served, wall_us) = drive(&engine, &queries, clients, passes);
            qps_off = served as f64 / secs;
            println!(
                "batching off: {served} queries in {secs:.3}s → {qps_off:8.1} q/s \
                 (mean wall {}µs/query)",
                wall_us / served.max(1)
            );
        } else {
            let sched = ctx.builder.scheduler(engine.clone());
            let (secs, served, wall_us) =
                drive_with(|q| sched.handle(q), &queries, clients, passes);
            qps_on = served as f64 / secs;
            let s = sched.stats();
            println!(
                "batching on:  {served} queries in {secs:.3}s → {qps_on:8.1} q/s \
                 (vs off ×{:.2}, mean wall {}µs/query)",
                qps_on / qps_off,
                wall_us / served.max(1)
            );
            println!(
                "              embed occupancy {:.1} ({} batches, {} full-width, {} window-expired); \
                 probe occupancy {:.1} ({} batches); bypassed {}",
                s.embed.occupancy(),
                s.embed.batches,
                s.embed.full_width,
                s.embed.window_expired,
                s.probe.occupancy(),
                s.probe.batches,
                s.bypassed,
            );
        }
    }
    println!(
        "acceptance: batching on ×{:.2} vs off at {clients} clients \
         (bit-identical results; fused-call occupancy above shows the \
         dispatch amortization the compiled backend banks on)",
        qps_on / qps_off
    );

    // ---- rebalance sweep: skewed placement, live migration, spread ----
    // Worst-case drift: every cluster on shard 0 (what round-robin decay
    // looks like in the limit). Bounded rebalance rounds must pull the
    // per-shard load spread down while queries keep serving identical
    // results (rust/tests/rebalance_churn.rs pins the bit-identity; this
    // sweep reports the load numbers).
    let clients = 4;
    println!("\n== rebalance sweep: 4 shards, {clients} client threads ==");
    let mut b = ctx.builder.clone();
    b.retrieval.shards = 4;
    let engine = b
        .pipeline(&built, IndexKind::EdgeRag)
        .expect("build sharded engine");
    for q in &queries {
        engine.handle(q).unwrap();
    }
    {
        let index = engine.index();
        let sharded = index
            .as_any()
            .downcast_ref::<edgerag::index::ShardedEdgeIndex>()
            .expect("shards=4 builds the sharded index");
        let globals: Vec<u32> = sharded
            .cluster_loads()
            .iter()
            .flatten()
            .map(|c| c.global)
            .collect();
        for &g in &globals {
            sharded.migrate_cluster(g, 0).expect("skew migration");
        }
        let rows = |s: &edgerag::index::ShardStats| s.rows;
        let spread_before = sharded.load_spread();
        let per_shard: Vec<u64> = sharded.shard_stats().iter().map(rows).collect();
        println!("skewed:     spread {spread_before:6} rows, per-shard {per_shard:?}");

        let (mut rounds, mut migrations) = (0usize, 0usize);
        loop {
            let r = sharded.rebalance().expect("rebalance round");
            rounds += 1;
            migrations += r.migrated;
            if r.migrated == 0 || rounds >= 16 {
                break;
            }
        }
        let spread_after = sharded.load_spread();
        let per_shard: Vec<u64> = sharded.shard_stats().iter().map(rows).collect();
        println!(
            "rebalanced: spread {spread_after:6} rows, per-shard {per_shard:?} \
             ({migrations} migrations over {rounds} rounds, ≤4 per round)"
        );
        println!(
            "acceptance: post-rebalance load spread ×{:.2} of the skewed \
             spread (target ≤0.5; searches stay bit-identical to the \
             single-shard oracle throughout — rebalance_churn.rs)",
            spread_after as f64 / spread_before.max(1) as f64
        );
    }
    let (secs, served, wall_us) = drive(&engine, &queries, clients, passes);
    println!(
        "post-rebalance serving: {served} queries in {secs:.3}s → {:8.1} q/s \
         (mean wall {}µs/query)",
        served as f64 / secs,
        wall_us / served.max(1)
    );
}
