//! Dataset sweep: the Fig. 13 experiment as a runnable example — TTFT for
//! all five index configurations across the BEIR-suite profiles, printing
//! the paper's headline comparison.
//!
//!     cargo run --release --example dataset_sweep [-- --small] [-- --full]
//!
//! `--small` restricts to the in-memory datasets (fast); default runs all
//! six at the default query budget; `--full` evaluates every workload
//! query.

use anyhow::Result;
use edgerag::config::DeviceProfile;
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::eval::experiments::{self, ExperimentCtx, DEFAULT_QUERY_LIMIT};
use edgerag::runtime::ComputeHandle;
use edgerag::testutil::artifacts_dir;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let small = args.iter().any(|a| a == "--small");
    let full = args.iter().any(|a| a == "--full");

    let compute = ComputeHandle::start(&artifacts_dir())?;
    let builder = SystemBuilder::new(compute, DeviceProfile::jetson_orin_nano());
    let ctx = ExperimentCtx {
        builder,
        query_limit: if full { None } else { Some(DEFAULT_QUERY_LIMIT) },
    };

    if small {
        // Small subset: just show the per-dataset trend quickly.
        for name in ["scidocs", "fiqa"] {
            let built = ctx.build(name)?;
            for kind in edgerag::config::IndexKind::ALL {
                let r = edgerag::eval::run_workload(
                    &ctx.builder,
                    &built,
                    kind,
                    &ctx.opts(),
                )?;
                println!(
                    "{name:<8} {:<13} retrieval {:>8} ttft {:>8} recall {:.3}",
                    kind.name(),
                    format!("{}", r.retrieval_mean),
                    format!("{}", r.ttft_mean),
                    r.quality.recall
                );
            }
        }
        return Ok(());
    }

    experiments::fig13(&ctx)?;
    experiments::headline(&ctx)?;
    Ok(())
}
