//! End-to-end serving driver (the DESIGN.md §5 "e2e validation" example):
//! starts the real TCP server over an EdgeRAG index, drives a batch of
//! client requests over the wire, and reports latency/throughput — the
//! serving-paper analogue of "load a small real model and serve batched
//! requests".
//!
//!     cargo run --release --example edge_assistant
//!
//! Everything is live: transformer embedder, live online generation,
//! real compiled prefill, real TCP round-trips. The workload replays the
//! dataset's query trace (with its Table-2 reuse skew) plus online
//! insertions mid-stream.

use std::time::Instant;

use anyhow::Result;
use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::embedding::EmbedderBackend;
use edgerag::json::Value;
use edgerag::runtime::ComputeHandle;
use edgerag::server::{Client, Server};
use edgerag::testutil::artifacts_dir;

fn main() -> Result<()> {
    println!("== edge_assistant: end-to-end serving over TCP ==");
    let compute = ComputeHandle::start(&artifacts_dir())?;
    let mut builder = SystemBuilder::new(compute, DeviceProfile::jetson_orin_nano());
    builder.options.backend = EmbedderBackend::Transformer;
    builder.options.real_prefill = true;
    builder.options.prebuilt_generation = false; // fully live generation
    builder.options.cache_dir = None;
    builder.retrieval.nprobe = 4;

    let profile = DatasetProfile::tiny();
    let built = builder.build_dataset(&profile)?;
    let n_queries = 48.min(built.workload.len());
    let queries: Vec<String> = built
        .workload
        .queries
        .iter()
        .take(n_queries)
        .map(|q| q.text.clone())
        .collect();

    let pipeline = builder.pipeline(&built, IndexKind::EdgeRag)?;
    let server = Server::bind("127.0.0.1:0", pipeline, builder.embedder())?;
    let addr = server.local_addr()?;
    println!("server on {addr}, corpus {} chunks", built.corpus.len());
    std::thread::spawn(move || server.run().unwrap());

    let mut client = Client::connect(&addr.to_string())?;
    // sanity ping
    let pong = client.call(&Value::object(vec![("op", Value::str("ping"))]))?;
    assert_eq!(pong.get("ok").and_then(|v| v.as_bool()), Some(true));

    let start = Instant::now();
    let mut modeled_ttft_ms = Vec::new();
    let mut cache_hits = 0u64;
    for (i, q) in queries.iter().enumerate() {
        let resp = client.query(q)?;
        let ttft = resp.get("ttft_ms").and_then(|v| v.as_f64()).unwrap();
        modeled_ttft_ms.push(ttft);
        cache_hits += resp.get("cache_hits").and_then(|v| v.as_u64()).unwrap_or(0);

        // Mid-stream online update: insert a fresh document and verify it
        // becomes retrievable (paper §5.4).
        if i == n_queries / 2 {
            let doc = "freshly ingested memo about quarterly roadmap zzviq";
            let ins = client.call(&Value::object(vec![
                ("op", Value::str("insert")),
                ("text", Value::str(doc)),
            ]))?;
            let id = ins.get("id").and_then(|v| v.as_u64()).expect("insert failed");
            let hit = client.query("quarterly roadmap memo zzviq")?;
            let ids: Vec<u64> = hit
                .get("hits")
                .and_then(|v| v.as_array())
                .unwrap()
                .iter()
                .map(|h| h.get("chunk").unwrap().as_u64().unwrap())
                .collect();
            assert!(
                ids.contains(&id),
                "inserted doc {id} not retrieved: {ids:?}"
            );
            println!("  [i={i}] online insert verified: doc {id} retrievable");
        }
    }
    let wall = start.elapsed();

    modeled_ttft_ms.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let p = |q: f64| modeled_ttft_ms[((q * n_queries as f64) as usize).min(n_queries - 1)];
    println!(
        "\nserved {n_queries} queries over TCP in {:.2}s → {:.1} q/s real throughput",
        wall.as_secs_f64(),
        n_queries as f64 / wall.as_secs_f64()
    );
    println!(
        "modeled device TTFT: p50 {:.0}ms p95 {:.0}ms (SLO {}ms) · cache hits {}",
        p(0.5),
        p(0.95),
        profile.slo_ms,
        cache_hits
    );

    let stats = client.call(&Value::object(vec![("op", Value::str("stats"))]))?;
    println!("server stats: {}", stats.pretty());
    let _ = client.call(&Value::object(vec![("op", Value::str("shutdown"))]));
    println!("edge_assistant OK");
    Ok(())
}
