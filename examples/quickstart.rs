//! Quickstart: build an EdgeRAG index over a small corpus and serve a few
//! queries through the full three-layer stack (rust coordinator → PJRT →
//! AOT-compiled JAX/Pallas graphs).
//!
//!     cargo run --release --example quickstart
//!
//! Uses the *transformer* embedding backend and real compiled prefill so
//! every layer is genuinely exercised.

use anyhow::Result;
use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::embedding::EmbedderBackend;
use edgerag::runtime::ComputeHandle;
use edgerag::testutil::artifacts_dir;

fn main() -> Result<()> {
    println!("== EdgeRAG quickstart ==");
    let compute = ComputeHandle::start(&artifacts_dir())?;
    println!(
        "compute executor up: {} artifacts, dim={}",
        compute.manifest().artifacts.len(),
        compute.dim()
    );

    let mut builder = SystemBuilder::new(compute, DeviceProfile::jetson_orin_nano());
    builder.options.backend = EmbedderBackend::Transformer; // full encoder
    builder.options.real_prefill = true; // run the compiled decoder graph
    builder.options.prebuilt_generation = false; // live online generation
    builder.options.cache_dir = None; // build fresh
    builder.retrieval.nprobe = 4;

    let profile = DatasetProfile::tiny();
    println!(
        "building dataset `{}`: {} chunks, {} topics…",
        profile.name, profile.n_chunks, profile.n_topics
    );
    let built = builder.build_dataset(&profile)?;
    let pipeline = builder.pipeline(&built, IndexKind::EdgeRag)?;

    // Take three workload queries + one ad-hoc query.
    let mut texts: Vec<String> = built
        .workload
        .queries
        .iter()
        .take(3)
        .map(|q| q.text.clone())
        .collect();
    texts.push(built.corpus.chunks[7].text.clone());

    for (i, text) in texts.iter().enumerate() {
        let out = pipeline.handle(text)?;
        println!(
            "\nquery {i}: \"{}\"\n  top chunks: {:?}\n  retrieval {} · ttft {} · prompt {} tokens · first-token id {:?}\n  events: gen={} load={} cache={} (wall {:?})",
            &text[..text.len().min(60)],
            out.hits.iter().map(|h| h.0).collect::<Vec<_>>(),
            out.retrieval,
            out.ttft,
            out.prompt_tokens,
            out.first_token,
            out.events.generated,
            out.events.loaded,
            out.events.cache_hits,
            out.wall,
        );
    }

    // Repeat the first query: the cost-aware cache should now hit.
    let again = pipeline.handle(&texts[0])?;
    println!(
        "\nrepeat of query 0: cache hits = {} (retrieval {} vs cold)",
        again.events.cache_hits, again.retrieval
    );

    let m = pipeline.metrics();
    let retrieval = m.retrieval();
    println!(
        "\nserved {} queries: retrieval p50 {} p95 {}, ttft p95 {}",
        m.queries(),
        retrieval.percentile(50.0),
        retrieval.percentile(95.0),
        m.ttft().percentile(95.0),
    );
    println!("\nquickstart OK");
    Ok(())
}
