//! Online indexing lifecycle (paper §5.4): continuous insertion and
//! removal against a live EdgeRAG index — cluster growth re-triggering
//! selective storage, shrinkage triggering merges, and retrieval staying
//! correct throughout.
//!
//!     cargo run --release --example online_updates

use anyhow::Result;
use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::data::Rng;
use edgerag::index::{EdgeIndex, VectorIndex};
use edgerag::runtime::ComputeHandle;
use edgerag::testutil::artifacts_dir;

fn main() -> Result<()> {
    println!("== online_updates: §5.4 insertion/removal lifecycle ==");
    let compute = ComputeHandle::start(&artifacts_dir())?;
    let mut builder = SystemBuilder::new(compute, DeviceProfile::jetson_orin_nano());
    builder.options.cache_dir = None;
    builder.retrieval.nprobe = 4;

    let profile = DatasetProfile::tiny();
    let built = builder.build_dataset(&profile)?;
    let embedder = builder.embedder();
    let mut pipeline = builder.pipeline(&built, IndexKind::EdgeRag)?;

    let stats = |p: &mut edgerag::coordinator::RagPipeline, tag: &str| {
        let e = p
            .index_mut()
            .as_any_mut()
            .downcast_mut::<EdgeIndex>()
            .unwrap();
        println!(
            "[{tag}] active clusters {}, stored blobs {} ({} bytes), resident {} bytes",
            e.active_clusters(),
            e.stored_clusters(),
            e.stored_bytes(),
            0
        );
    };
    stats(&mut pipeline, "initial");

    // Phase 1: ingest a stream of new documents.
    let mut rng = Rng::new(2024);
    let mut next_id = built.corpus.len() as u32;
    let mut inserted = Vec::new();
    for i in 0..60 {
        let topic = rng.below(8);
        let text = format!(
            "live document {i} about topic t{topic} with words t{topic}w{} t{topic}w{} and marker live{i}",
            rng.below(48),
            rng.below(48),
        );
        let emb = embedder.embed_one(&text)?;
        let edge = pipeline
            .index_mut()
            .as_any_mut()
            .downcast_mut::<EdgeIndex>()
            .unwrap();
        let cluster = edge.insert_chunk(next_id, &text, &emb)?;
        inserted.push((next_id, text, cluster));
        next_id += 1;
    }
    stats(&mut pipeline, "after 60 inserts");

    // Verify each inserted doc is retrievable by its own content.
    let mut found = 0;
    for (id, text, _) in &inserted {
        let emb = embedder.embed_one(text)?;
        let edge = pipeline
            .index_mut()
            .as_any_mut()
            .downcast_mut::<EdgeIndex>()
            .unwrap();
        let out = edge.search(&emb, 5)?;
        if out.hits.iter().any(|h| h.0 == *id) {
            found += 1;
        }
    }
    println!("retrievable after insert: {found}/{}", inserted.len());
    assert!(found as f64 >= inserted.len() as f64 * 0.95);

    // Phase 2: remove half of them again (plus drain one small cluster to
    // force a merge).
    for (id, _, _) in inserted.iter().take(30) {
        let edge = pipeline
            .index_mut()
            .as_any_mut()
            .downcast_mut::<EdgeIndex>()
            .unwrap();
        assert!(edge.remove_chunk(*id)?);
    }
    stats(&mut pipeline, "after 30 removals");

    // Removed docs must be gone; survivors must remain.
    let edge_check = |p: &mut edgerag::coordinator::RagPipeline, id: u32, text: &str| -> Result<bool> {
        let emb = embedder.embed_one(text)?;
        let edge = p
            .index_mut()
            .as_any_mut()
            .downcast_mut::<EdgeIndex>()
            .unwrap();
        Ok(edge.search(&emb, 5)?.hits.iter().any(|h| h.0 == id))
    };
    let mut stale = 0;
    for (id, text, _) in inserted.iter().take(30) {
        if edge_check(&mut pipeline, *id, text)? {
            stale += 1;
        }
    }
    assert_eq!(stale, 0, "{stale} removed docs still retrievable");
    let mut survivors = 0;
    for (id, text, _) in inserted.iter().skip(30) {
        if edge_check(&mut pipeline, *id, text)? {
            survivors += 1;
        }
    }
    println!("survivors still retrievable: {survivors}/30, removed gone: 30/30");
    assert!(survivors >= 28);

    // Phase 3: queries still serve fine after all the churn.
    for q in built.workload.queries.iter().take(10) {
        let out = pipeline.handle(&q.text)?;
        assert!(!out.hits.is_empty());
    }
    println!("post-churn query serving OK");
    println!("online_updates OK");
    Ok(())
}
