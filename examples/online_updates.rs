//! Online indexing lifecycle (paper §5.4): continuous insertion and
//! removal against a live EdgeRAG index — cluster growth re-triggering
//! selective storage, shrinkage triggering merges, and retrieval staying
//! correct throughout. Mutations take the engine's index write lease;
//! searches use the shared read path.
//!
//!     cargo run --release --example online_updates

use anyhow::Result;
use edgerag::config::{DatasetProfile, DeviceProfile, IndexKind};
use edgerag::coordinator::builder::SystemBuilder;
use edgerag::coordinator::Engine;
use edgerag::data::Rng;
use edgerag::index::EdgeIndex;
use edgerag::runtime::ComputeHandle;
use edgerag::testutil::artifacts_dir;

/// Run `f` against the EdgeRAG index under the exclusive write lease.
fn with_edge<R>(engine: &Engine, f: impl FnOnce(&mut EdgeIndex) -> R) -> R {
    let mut index = engine.index_mut();
    let edge = index
        .as_any_mut()
        .downcast_mut::<EdgeIndex>()
        .expect("EdgeRAG index");
    f(edge)
}

fn main() -> Result<()> {
    println!("== online_updates: §5.4 insertion/removal lifecycle ==");
    let compute = ComputeHandle::start(&artifacts_dir())?;
    let mut builder = SystemBuilder::new(compute, DeviceProfile::jetson_orin_nano());
    builder.options.cache_dir = None;
    builder.retrieval.nprobe = 4;

    let profile = DatasetProfile::tiny();
    let built = builder.build_dataset(&profile)?;
    let embedder = builder.embedder();
    let pipeline = builder.pipeline(&built, IndexKind::EdgeRag)?;

    let stats = |p: &Engine, tag: &str| {
        with_edge(p, |e| {
            println!(
                "[{tag}] active clusters {}, stored blobs {} ({} bytes), resident {} bytes",
                e.active_clusters(),
                e.stored_clusters(),
                e.stored_bytes(),
                0
            );
        });
    };
    stats(&pipeline, "initial");

    // Phase 1: ingest a stream of new documents.
    let mut rng = Rng::new(2024);
    let mut next_id = built.corpus.len() as u32;
    let mut inserted = Vec::new();
    for i in 0..60 {
        let topic = rng.below(8);
        let text = format!(
            "live document {i} about topic t{topic} with words t{topic}w{} t{topic}w{} and marker live{i}",
            rng.below(48),
            rng.below(48),
        );
        let emb = embedder.embed_one(&text)?;
        let cluster = with_edge(&pipeline, |e| e.insert_chunk(next_id, &text, &emb))?;
        inserted.push((next_id, text, cluster));
        next_id += 1;
    }
    stats(&pipeline, "after 60 inserts");

    // Verify each inserted doc is retrievable by its own content —
    // through the shared read path, like a live query would be. The
    // commit applies the search's deferred cache admissions; skipping it
    // would silently leave the adaptive cache cold.
    let search_ids = |p: &Engine, text: &str| -> Result<Vec<u32>> {
        let emb = embedder.embed_one(text)?;
        let index = p.index();
        let out = index.search(&emb, 5)?;
        index.commit(&out.intents, out.ledger.retrieval());
        Ok(out.hits.iter().map(|h| h.0).collect())
    };
    let mut found = 0;
    for (id, text, _) in &inserted {
        if search_ids(&pipeline, text)?.contains(id) {
            found += 1;
        }
    }
    println!("retrievable after insert: {found}/{}", inserted.len());
    assert!(found as f64 >= inserted.len() as f64 * 0.95);

    // Phase 2: remove half of them again (plus drain one small cluster to
    // force a merge).
    for (id, _, _) in inserted.iter().take(30) {
        let removed = with_edge(&pipeline, |e| e.remove_chunk(*id))?;
        assert!(removed);
    }
    stats(&pipeline, "after 30 removals");

    // Removed docs must be gone; survivors must remain.
    let mut stale = 0;
    for (id, text, _) in inserted.iter().take(30) {
        if search_ids(&pipeline, text)?.contains(id) {
            stale += 1;
        }
    }
    assert_eq!(stale, 0, "{stale} removed docs still retrievable");
    let mut survivors = 0;
    for (id, text, _) in inserted.iter().skip(30) {
        if search_ids(&pipeline, text)?.contains(id) {
            survivors += 1;
        }
    }
    println!("survivors still retrievable: {survivors}/30, removed gone: 30/30");
    assert!(survivors >= 28);

    // Phase 3: queries still serve fine after all the churn.
    for q in built.workload.queries.iter().take(10) {
        let out = pipeline.handle(&q.text)?;
        assert!(!out.hits.is_empty());
    }
    println!("post-churn query serving OK");
    println!("online_updates OK");
    Ok(())
}
