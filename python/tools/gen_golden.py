"""Regenerate the cross-language golden files in tests/golden/.

    cd python && python tools/gen_golden.py

* tokenizer.json  — token ids for a fixed text set (rust + python tests)
* embeddings.json — projection + encoder embeddings computed by the jax/
  Pallas (interpret) path using the shipped artifact weights; the rust
  test re-computes them through PJRT-compiled HLO and compares.
"""

import json
import os
import sys

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

import numpy as np
import jax.numpy as jnp

from compile import model
from compile import tokenizer as tok

ROOT = os.path.join(os.path.dirname(os.path.abspath(__file__)), "..", "..")
GOLDEN = os.path.join(ROOT, "tests", "golden")
ARTIFACTS = os.path.join(ROOT, "artifacts")

TOKENIZER_TEXTS = [
    "hello world",
    "Hello, World!",
    "the quick brown fox jumps over the lazy dog",
    "EdgeRAG: Online-Indexed RAG for Edge Devices",
    "retrieval augmented generation 2024",
    "a",
    "  multiple   spaces\tand\nnewlines ",
    "123 456 alpha-beta_gamma",
    "repeated repeated repeated words words",
    "punctuation!!! only??? ...",
    "UTF ascii only caf test",
    "inverted file index clusters embeddings of data chunks into centroids",
]

EMBED_TEXTS = [
    "hello world",
    "edge devices run small language models efficiently",
    "t3w7 t3w12 c100 c200 retrieval augmented generation",
]


def main() -> None:
    os.makedirs(GOLDEN, exist_ok=True)

    cases = [{"text": t, "ids": tok.token_ids(t)} for t in TOKENIZER_TEXTS]
    with open(os.path.join(GOLDEN, "tokenizer.json"), "w") as f:
        json.dump(cases, f, indent=1)
    print(f"tokenizer.json: {len(cases)} cases")

    theta = np.fromfile(
        os.path.join(ARTIFACTS, "weights", "projection.bin"), dtype="<f4"
    )
    feats = np.stack([tok.features(t) for t in EMBED_TEXTS])
    (proj,) = model.projection_embed(jnp.asarray(theta), jnp.asarray(feats))

    enc_theta = np.fromfile(
        os.path.join(ARTIFACTS, "weights", "encoder.bin"), dtype="<f4"
    )
    pairs = [tok.sequence(t) for t in EMBED_TEXTS]
    ids = np.stack([p[0] for p in pairs])
    mask = np.stack([p[1] for p in pairs])
    (enc,) = model.encoder_embed(
        jnp.asarray(enc_theta), jnp.asarray(ids), jnp.asarray(mask)
    )

    out = {
        "texts": EMBED_TEXTS,
        "projection": np.asarray(proj).astype(float).round(6).tolist(),
        "encoder": np.asarray(enc).astype(float).round(6).tolist(),
    }
    with open(os.path.join(GOLDEN, "embeddings.json"), "w") as f:
        json.dump(out, f)
    print(f"embeddings.json: {np.asarray(proj).shape} + {np.asarray(enc).shape}")


if __name__ == "__main__":
    main()
