"""Tokenizer: invariants + cross-language golden vectors.

The golden file (tests/golden/tokenizer.json at the repo root) is consumed
by BOTH this test and `rust/tests/tokenizer_golden.rs` — the two
implementations must agree bit-for-bit since rust tokenizes on the serving
path and python at kernel-validation time.
"""

import json
import os

import numpy as np
from hypothesis import given, strategies as st

from compile import tokenizer as tok

GOLDEN = os.path.join(os.path.dirname(__file__), "..", "..",
                      "tests", "golden", "tokenizer.json")


def test_golden_vectors():
    with open(GOLDEN) as f:
        cases = json.load(f)
    assert len(cases) >= 8
    for case in cases:
        assert tok.token_ids(case["text"]) == case["ids"], case["text"]


def test_fnv1a_known_values():
    # Published FNV-1a 32-bit test vectors.
    assert tok.fnv1a32(b"") == 0x811C9DC5
    assert tok.fnv1a32(b"a") == 0xE40C292C
    assert tok.fnv1a32(b"foobar") == 0xBF9CF968


@given(st.text(max_size=200))
def test_ids_in_range(text):
    for tid in tok.token_ids(text):
        assert 2 <= tid < tok.VOCAB


@given(st.text(alphabet=st.characters(max_codepoint=127), max_size=200))
def test_case_insensitive(text):
    # ASCII-only property: non-ascii characters may case-map INTO ascii
    # (e.g. 'ſ'.upper() == 'S'), which legitimately changes tokenization.
    assert tok.token_ids(text) == tok.token_ids(text.upper())


@given(st.text(max_size=100))
def test_features_match_ids(text):
    f = tok.features(text)
    ids = tok.token_ids(text)
    assert f.sum() == len(ids)
    for tid in set(ids):
        assert f[tid] == ids.count(tid)


def test_sequence_layout():
    ids, mask = tok.sequence("hello world")
    assert ids[0] == tok.CLS_ID
    assert mask[:3].tolist() == [1.0, 1.0, 1.0]
    assert mask[3:].sum() == 0
    assert ids[3:].sum() == 0


def test_sequence_truncation():
    text = " ".join(f"w{i}" for i in range(500))
    ids, mask = tok.sequence(text)
    assert len(ids) == tok.SEQ_LEN
    assert mask.sum() == tok.SEQ_LEN


def test_empty_text():
    assert tok.token_ids("") == []
    assert tok.features("").sum() == 0
    ids, mask = tok.sequence("")
    assert ids[0] == tok.CLS_ID and mask.sum() == 1.0
