"""L1 attention kernel vs oracle: padding masks, causal masks, stability."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.attention import attention
from compile.kernels.ref import attention_ref


def _qkv(rng, bh, s, dh):
    q = jnp.asarray(rng.standard_normal((bh, s, dh)), dtype=jnp.float32)
    k = jnp.asarray(rng.standard_normal((bh, s, dh)), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((bh, s, dh)), dtype=jnp.float32)
    return q, k, v


def _mask(rng, bh, s):
    lens = rng.integers(1, s + 1, bh)
    m = np.zeros((bh, s), dtype=np.float32)
    for i, L in enumerate(lens):
        m[i, :L] = 1.0
    return jnp.asarray(m)


@given(
    bh=st.sampled_from([1, 4, 16]),
    s=st.sampled_from([8, 64, 128]),
    dh=st.sampled_from([16, 64]),
    causal=st.booleans(),
    seed=st.integers(0, 2**16),
)
def test_matches_ref(bh, s, dh, causal, seed):
    rng = np.random.default_rng(seed)
    q, k, v = _qkv(rng, bh, s, dh)
    m = _mask(rng, bh, s)
    got = attention(q, k, v, m, causal=causal)
    want = attention_ref(q, k, v, m, causal=causal)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-4, atol=2e-4)


def test_padding_keys_have_no_influence():
    """Changing values at masked-out key positions must not change output."""
    rng = np.random.default_rng(3)
    q, k, v = _qkv(rng, 2, 16, 32)
    m = np.ones((2, 16), dtype=np.float32)
    m[:, 10:] = 0.0
    m = jnp.asarray(m)
    out1 = attention(q, k, v, m)
    k2 = k.at[:, 10:, :].set(999.0)
    v2 = v.at[:, 10:, :].set(-999.0)
    out2 = attention(q, k2, v2, m)
    assert_allclose(np.asarray(out1), np.asarray(out2), rtol=1e-5, atol=1e-5)


def test_causal_future_has_no_influence():
    rng = np.random.default_rng(4)
    q, k, v = _qkv(rng, 1, 32, 16)
    m = jnp.ones((1, 32), dtype=jnp.float32)
    out1 = attention(q, k, v, m, causal=True)
    k2 = k.at[:, 20:, :].set(123.0)
    v2 = v.at[:, 20:, :].set(-123.0)
    out2 = attention(q, k2, v2, m, causal=True)
    # positions < 20 must be identical
    assert_allclose(np.asarray(out1)[:, :20], np.asarray(out2)[:, :20],
                    rtol=1e-5, atol=1e-5)


def test_softmax_stability_large_logits():
    rng = np.random.default_rng(5)
    q, k, v = _qkv(rng, 1, 8, 16)
    q = q * 1e3  # huge logits — unstabilized softmax would overflow
    m = jnp.ones((1, 8), dtype=jnp.float32)
    out = np.asarray(attention(q, k, v, m))
    assert np.isfinite(out).all()


def test_uniform_attention_when_keys_equal():
    """Identical keys ⇒ output = mean of values."""
    rng = np.random.default_rng(6)
    q = jnp.asarray(rng.standard_normal((1, 4, 8)), dtype=jnp.float32)
    k = jnp.ones((1, 4, 8), dtype=jnp.float32)
    v = jnp.asarray(rng.standard_normal((1, 4, 8)), dtype=jnp.float32)
    m = jnp.ones((1, 4), dtype=jnp.float32)
    out = np.asarray(attention(q, k, v, m))
    want = np.broadcast_to(np.asarray(v).mean(axis=1, keepdims=True),
                           out.shape)
    assert_allclose(out, want, rtol=1e-5, atol=1e-5)
