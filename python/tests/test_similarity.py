"""L1 similarity kernel vs pure-jnp oracle: shape/dtype sweep."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st
from numpy.testing import assert_allclose

from compile.kernels.ref import similarity_ref
from compile.kernels.similarity import similarity


def _rand(rng, *shape):
    return jnp.asarray(rng.standard_normal(shape), dtype=jnp.float32)


@given(
    b=st.sampled_from([1, 2, 8, 32]),
    n=st.sampled_from([8, 128, 256, 384, 1024]),
    d=st.sampled_from([32, 128, 256]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref(b, n, d, seed):
    rng = np.random.default_rng(seed)
    q, e = _rand(rng, b, d), _rand(rng, n, d)
    got = similarity(q, e)
    want = similarity_ref(q, e)
    assert got.shape == (b, n)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@given(
    block_n=st.sampled_from([32, 64, 128]),
    seed=st.integers(0, 2**16),
)
def test_block_size_invariance(block_n, seed):
    """Scores must not depend on the tiling choice."""
    rng = np.random.default_rng(seed)
    q, e = _rand(rng, 4, 128), _rand(rng, 256, 128)
    got = similarity(q, e, block_n=block_n)
    want = similarity_ref(q, e)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


def test_unit_vectors_cosine_bounds():
    rng = np.random.default_rng(0)
    q = rng.standard_normal((2, 64)).astype(np.float32)
    e = rng.standard_normal((128, 64)).astype(np.float32)
    q /= np.linalg.norm(q, axis=1, keepdims=True)
    e /= np.linalg.norm(e, axis=1, keepdims=True)
    s = np.asarray(similarity(jnp.asarray(q), jnp.asarray(e)))
    assert np.all(s <= 1.0 + 1e-5) and np.all(s >= -1.0 - 1e-5)


def test_self_similarity_is_max():
    rng = np.random.default_rng(1)
    e = rng.standard_normal((128, 64)).astype(np.float32)
    e /= np.linalg.norm(e, axis=1, keepdims=True)
    s = np.asarray(similarity(jnp.asarray(e[:4]), jnp.asarray(e)))
    assert (s.argmax(axis=1) == np.arange(4)).all()


def test_non_multiple_n_falls_back():
    rng = np.random.default_rng(2)
    q = jnp.asarray(rng.standard_normal((1, 64)), dtype=jnp.float32)
    e = jnp.asarray(rng.standard_normal((100, 64)), dtype=jnp.float32)
    got = similarity(q, e)  # 100 % 128 != 0 → single-tile fallback
    assert_allclose(np.asarray(got), np.asarray(similarity_ref(q, e)),
                    rtol=2e-5, atol=2e-5)
