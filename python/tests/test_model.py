"""L2 model graphs: shapes, determinism, pooling/masking semantics."""

import jax.numpy as jnp
import numpy as np
from numpy.testing import assert_allclose

from compile import model


def _enc_theta(seed=2):
    return jnp.asarray(
        model.transformer_pack(model.ENC_LAYERS, causal=False).init(seed))


def _pre_theta(seed=3):
    return jnp.asarray(
        model.transformer_pack(model.PREFILL_LAYERS, causal=True).init(seed))


def test_param_pack_roundtrip():
    p = model.transformer_pack(2, causal=True)
    theta = jnp.arange(p.total, dtype=jnp.float32)
    sl = p.slices(theta)
    # every element is covered exactly once, in order
    flat = jnp.concatenate([sl[n].reshape(-1) for n, _ in p.entries])
    assert_allclose(np.asarray(flat), np.asarray(theta))


def test_param_init_deterministic():
    p = model.projection_pack()
    assert_allclose(p.init(1), p.init(1))
    assert not np.allclose(p.init(1), p.init(2))


def test_encoder_shapes_and_norm():
    theta = _enc_theta()
    ids = jnp.zeros((2, model.ENC_SEQ), dtype=jnp.int32)
    ids = ids.at[:, 0].set(1).at[0, 1:5].set(jnp.asarray([10, 20, 30, 40]))
    mask = (ids != 0).astype(jnp.float32).at[:, 0].set(1.0)
    (e,) = model.encoder_embed(theta, ids, mask)
    assert e.shape == (2, model.DIM)
    assert_allclose(np.linalg.norm(np.asarray(e), axis=1), np.ones(2),
                    rtol=1e-4)


def test_encoder_padding_invariance():
    """Garbage in padded positions must not change the embedding."""
    theta = _enc_theta()
    ids = np.zeros((1, model.ENC_SEQ), dtype=np.int32)
    ids[0, :6] = [1, 11, 22, 33, 44, 55]
    mask = np.zeros((1, model.ENC_SEQ), dtype=np.float32)
    mask[0, :6] = 1.0
    (e1,) = model.encoder_embed(theta, jnp.asarray(ids), jnp.asarray(mask))
    ids2 = ids.copy()
    ids2[0, 6:] = 777  # garbage beyond the mask
    (e2,) = model.encoder_embed(theta, jnp.asarray(ids2), jnp.asarray(mask))
    assert_allclose(np.asarray(e1), np.asarray(e2), rtol=1e-5, atol=1e-5)


def test_encoder_batch_consistency():
    """Row i of a batched call equals a singleton call (buckets can't change
    the numbers)."""
    theta = _enc_theta()
    rng = np.random.default_rng(0)
    ids = rng.integers(2, model.VOCAB, (8, model.ENC_SEQ)).astype(np.int32)
    ids[:, 0] = 1
    mask = np.ones((8, model.ENC_SEQ), dtype=np.float32)
    (full,) = model.encoder_embed(theta, jnp.asarray(ids), jnp.asarray(mask))
    (one,) = model.encoder_embed(theta, jnp.asarray(ids[3:4]),
                                 jnp.asarray(mask[3:4]))
    assert_allclose(np.asarray(full)[3], np.asarray(one)[0],
                    rtol=1e-4, atol=1e-4)


def test_prefill_shapes_and_finite():
    theta = _pre_theta()
    ids = np.zeros((1, model.PREFILL_SEQ), dtype=np.int32)
    ids[0, :10] = np.arange(1, 11)
    (logits,) = model.prefill_logits(theta, jnp.asarray(ids))
    assert logits.shape == (1, model.VOCAB)
    assert np.isfinite(np.asarray(logits)).all()


def test_prefill_uses_last_valid_position():
    """Appending a token after padding start must change logits; garbage in
    the padded tail must not."""
    theta = _pre_theta()
    ids = np.zeros((1, model.PREFILL_SEQ), dtype=np.int32)
    ids[0, :5] = [1, 7, 8, 9, 10]
    (l1,) = model.prefill_logits(theta, jnp.asarray(ids))
    ids2 = ids.copy()
    ids2[0, 5] = 42  # one more real token
    (l2,) = model.prefill_logits(theta, jnp.asarray(ids2))
    assert not np.allclose(np.asarray(l1), np.asarray(l2))


def test_scores_graph_matches_matmul():
    rng = np.random.default_rng(1)
    q = jnp.asarray(rng.standard_normal((1, model.DIM)), dtype=jnp.float32)
    e = jnp.asarray(rng.standard_normal((128, model.DIM)), dtype=jnp.float32)
    (s,) = model.scores(q, e)
    assert_allclose(np.asarray(s), np.asarray(q @ e.T), rtol=2e-5, atol=2e-5)
