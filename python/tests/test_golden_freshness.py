"""The committed golden files must match what the current code + shipped
weights produce (catches drift between tokenizer/model changes and the
cross-language contract)."""

import json
import os

import numpy as np
import jax.numpy as jnp
import pytest

from compile import model
from compile import tokenizer as tok

ROOT = os.path.join(os.path.dirname(__file__), "..", "..")
GOLDEN = os.path.join(ROOT, "tests", "golden")
ARTIFACTS = os.path.join(ROOT, "artifacts")


def test_embedding_golden_fresh():
    path = os.path.join(GOLDEN, "embeddings.json")
    if not os.path.exists(path):
        pytest.skip("golden not generated")
    with open(path) as f:
        g = json.load(f)
    theta = np.fromfile(
        os.path.join(ARTIFACTS, "weights", "projection.bin"), dtype="<f4"
    )
    feats = np.stack([tok.features(t) for t in g["texts"]])
    (proj,) = model.projection_embed(jnp.asarray(theta), jnp.asarray(feats))
    np.testing.assert_allclose(
        np.asarray(proj), np.asarray(g["projection"]), atol=2e-6
    )

    enc_theta = np.fromfile(
        os.path.join(ARTIFACTS, "weights", "encoder.bin"), dtype="<f4"
    )
    pairs = [tok.sequence(t) for t in g["texts"]]
    ids = np.stack([p[0] for p in pairs])
    mask = np.stack([p[1] for p in pairs])
    (enc,) = model.encoder_embed(
        jnp.asarray(enc_theta), jnp.asarray(ids), jnp.asarray(mask)
    )
    np.testing.assert_allclose(np.asarray(enc), np.asarray(g["encoder"]), atol=2e-6)
