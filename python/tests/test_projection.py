"""L1 projection kernel vs oracle + embedding invariants."""

import jax.numpy as jnp
import numpy as np
from hypothesis import given, strategies as st
from numpy.testing import assert_allclose

from compile import model
from compile.kernels.projection import project
from compile.kernels.ref import projection_ref


def _pack(rng, vocab, dim):
    theta = rng.standard_normal(vocab * dim + dim).astype(np.float32)
    return jnp.asarray(theta)


@given(
    b=st.sampled_from([1, 4, 32]),
    vocab=st.sampled_from([256, 1024, 4096]),
    dim=st.sampled_from([64, 256]),
    seed=st.integers(0, 2**16),
)
def test_matches_ref(b, vocab, dim, seed):
    rng = np.random.default_rng(seed)
    theta = _pack(rng, vocab, dim)
    feats = jnp.asarray(
        rng.poisson(0.01, (b, vocab)).astype(np.float32))
    w = theta[: vocab * dim].reshape(vocab, dim)
    bias = theta[vocab * dim:]
    got = project(feats, w, bias)
    want = projection_ref(theta, feats, dim=dim)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


@given(block_k=st.sampled_from([128, 256, 512, 1024]), seed=st.integers(0, 99))
def test_block_k_invariance(block_k, seed):
    rng = np.random.default_rng(seed)
    vocab, dim, b = 1024, 64, 4
    theta = _pack(rng, vocab, dim)
    feats = jnp.asarray(rng.poisson(0.05, (b, vocab)).astype(np.float32))
    w = theta[: vocab * dim].reshape(vocab, dim)
    bias = theta[vocab * dim:]
    got = project(feats, w, bias, block_k=block_k)
    want = projection_ref(theta, feats, dim=dim)
    assert_allclose(np.asarray(got), np.asarray(want), rtol=3e-5, atol=3e-5)


def test_output_is_unit_norm():
    rng = np.random.default_rng(7)
    theta = _pack(rng, 4096, 256)
    feats = jnp.asarray(rng.poisson(0.01, (8, 4096)).astype(np.float32))
    out = np.asarray(model.projection_embed(theta, feats)[0])
    assert_allclose(np.linalg.norm(out, axis=1), np.ones(8), rtol=1e-4)


def test_similar_texts_closer_than_dissimilar():
    """The embedding must preserve token-overlap structure (what retrieval
    quality experiments depend on)."""
    rng = np.random.default_rng(8)
    theta = _pack(rng, 4096, 256)
    base = rng.poisson(0.02, 4096).astype(np.float32)
    near = base.copy()
    near[rng.integers(0, 4096, 5)] += 1.0            # small perturbation
    far = rng.poisson(0.02, 4096).astype(np.float32)  # unrelated
    feats = jnp.asarray(np.stack([base, near, far]))
    e = np.asarray(model.projection_embed(theta, feats)[0])
    assert e[0] @ e[1] > e[0] @ e[2]


def test_zero_features_finite():
    rng = np.random.default_rng(9)
    theta = _pack(rng, 1024, 64)
    feats = jnp.zeros((2, 1024), dtype=jnp.float32)
    w = theta[: 1024 * 64].reshape(1024, 64)
    bias = theta[1024 * 64:]
    out = np.asarray(project(feats, w, bias))
    assert np.isfinite(out).all()
