import os
import sys

# Tests import `compile.*` the same way aot.py is invoked (from python/).
sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(__file__))))

from hypothesis import settings

# Pallas interpret-mode is slow; keep example counts modest but meaningful.
settings.register_profile("ci", max_examples=25, deadline=None)
settings.load_profile("ci")
