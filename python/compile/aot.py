"""AOT pipeline: lower every Layer-2 graph to HLO *text* + write weights
and a manifest the rust runtime consumes.

Run once at build time (`make artifacts`):

    cd python && python -m compile.aot --out ../artifacts

Interchange is HLO text, NOT `.serialize()`: jax ≥ 0.5 emits HloModuleProto
with 64-bit instruction ids which the image's xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Outputs under --out:
  manifest.json          artifact registry: name → hlo file, input specs
                         (weight blobs vs runtime inputs), output specs
  weights/*.bin          flat little-endian f32 weight blobs (seeded)
  *.hlo.txt              one HLO module per (graph, shape-bucket)
"""

from __future__ import annotations

import argparse
import json
import os

import jax
import jax.numpy as jnp
import numpy as np
from jax._src.lib import xla_client as xc

from . import model

# Shape buckets — must match rust/src/runtime/manifest.rs (Manifest::builtin).
# The query-batch axis serves the cross-query batch scheduler
# (rust/src/sched): concurrent queries' centroid probes fuse into one
# sim_{A}x{N} call at the widest bucket that fits.
SIM_QUERY_BATCHES = [1, 8, 32]
SIM_ROWS = [128, 256, 512, 1024, 4096]
KMEANS_SIM = (32, 512)          # (points-batch, max-centroids)
PROJ_BATCHES = [1, 32]
ENC_BATCHES = [1, 8]

SEEDS = {"projection": 1, "encoder": 2, "prefill": 3}


def to_hlo_text(lowered) -> str:
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype="f32", kind="input", file=None):
    d = {"kind": kind, "dtype": dtype, "shape": list(shape)}
    if file is not None:
        d["file"] = file
    return d


def _write_weights(out_dir: str, name: str, pack: model.ParamPack) -> str:
    os.makedirs(os.path.join(out_dir, "weights"), exist_ok=True)
    rel = f"weights/{name}.bin"
    theta = pack.init(SEEDS[name])
    theta.astype("<f4").tofile(os.path.join(out_dir, rel))
    return rel


def build_all(out_dir: str) -> dict:
    os.makedirs(out_dir, exist_ok=True)
    artifacts = []

    def lower(name: str, fn, example_args, inputs):
        lowered = jax.jit(fn).lower(*example_args)
        text = to_hlo_text(lowered)
        hlo = f"{name}.hlo.txt"
        with open(os.path.join(out_dir, hlo), "w") as f:
            f.write(text)
        out_avals = jax.eval_shape(fn, *example_args)
        outputs = [
            _spec(o.shape, "f32" if o.dtype == jnp.float32 else str(o.dtype))
            for o in out_avals
        ]
        artifacts.append(
            {"name": name, "hlo": hlo, "inputs": inputs, "outputs": outputs}
        )
        print(f"  {name:<14} {hlo:<22} {len(text) / 1024:8.1f} KiB")

    d = model.DIM
    f32 = jnp.float32

    # ---- similarity scorers (level-1 centroids, level-2 clusters, flat) ----
    for b in SIM_QUERY_BATCHES:
        for n in SIM_ROWS:
            lower(
                f"sim_{b}x{n}",
                model.scores,
                (jax.ShapeDtypeStruct((b, d), f32),
                 jax.ShapeDtypeStruct((n, d), f32)),
                [_spec((b, d)), _spec((n, d))],
            )
    kb, kn = KMEANS_SIM
    if kb not in SIM_QUERY_BATCHES or kn not in SIM_ROWS:
        # The k-means shape is usually part of the cross product above;
        # lower it explicitly only when the grids drift apart.
        lower(
            f"sim_{kb}x{kn}",
            model.scores,
            (jax.ShapeDtypeStruct((kb, d), f32),
             jax.ShapeDtypeStruct((kn, d), f32)),
            [_spec((kb, d)), _spec((kn, d))],
        )

    # ---- projection embedder ----
    pp = model.projection_pack()
    proj_w = _write_weights(out_dir, "projection", pp)
    for b in PROJ_BATCHES:
        lower(
            f"proj_{b}",
            model.projection_embed,
            (jax.ShapeDtypeStruct((pp.total,), f32),
             jax.ShapeDtypeStruct((b, model.VOCAB), f32)),
            [_spec((pp.total,), kind="weight", file=proj_w),
             _spec((b, model.VOCAB))],
        )

    # ---- transformer encoder embedder ----
    ep = model.transformer_pack(model.ENC_LAYERS, causal=False)
    enc_w = _write_weights(out_dir, "encoder", ep)
    for b in ENC_BATCHES:
        lower(
            f"enc_{b}",
            model.encoder_embed,
            (jax.ShapeDtypeStruct((ep.total,), f32),
             jax.ShapeDtypeStruct((b, model.ENC_SEQ), jnp.int32),
             jax.ShapeDtypeStruct((b, model.ENC_SEQ), f32)),
            [_spec((ep.total,), kind="weight", file=enc_w),
             _spec((b, model.ENC_SEQ), dtype="i32"),
             _spec((b, model.ENC_SEQ))],
        )

    # ---- LLM prefill proxy ----
    fp = model.transformer_pack(model.PREFILL_LAYERS, causal=True)
    pre_w = _write_weights(out_dir, "prefill", fp)
    lower(
        "prefill_1",
        model.prefill_logits,
        (jax.ShapeDtypeStruct((fp.total,), f32),
         jax.ShapeDtypeStruct((1, model.PREFILL_SEQ), jnp.int32)),
        [_spec((fp.total,), kind="weight", file=pre_w),
         _spec((1, model.PREFILL_SEQ), dtype="i32")],
    )

    manifest = {
        "dim": model.DIM,
        "vocab": model.VOCAB,
        "enc_seq": model.ENC_SEQ,
        "prefill_seq": model.PREFILL_SEQ,
        "sim_rows": SIM_ROWS,
        "sim_batches": SIM_QUERY_BATCHES,
        "proj_batches": PROJ_BATCHES,
        "enc_batches": ENC_BATCHES,
        "artifacts": artifacts,
    }
    with open(os.path.join(out_dir, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    return manifest


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    args = ap.parse_args()
    print(f"lowering EdgeRAG graphs → {args.out}")
    m = build_all(args.out)
    print(f"wrote {len(m['artifacts'])} artifacts + manifest.json")


if __name__ == "__main__":
    main()
