"""Layer 2: the JAX compute graphs EdgeRAG serves, all calling the Layer-1
Pallas kernels. AOT-lowered once by `aot.py`; never imported at runtime.

Graphs
------
* `projection_embed` — the fast hash-projection embedder (kernel:
  `projection.project`). Online embedding generation runs through this.
* `encoder_embed`    — gte-style transformer encoder (kernel:
  `attention.attention`), mean-pooled + L2-normalized. The "full" embedder
  used by the e2e example.
* `scores`           — similarity scoring (kernel: `similarity.similarity`)
  for both IVF levels and the flat baseline.
* `prefill_logits`   — causal decoder prefill proxy: first-output-token
  logits for TTFT's prefill component.

All weights are packed into a single flat f32 `theta` parameter so the rust
runtime feeds exactly one weight literal per executable (see
`ParamPack`). Weight values are seeded-deterministic: python and rust both
read the same `artifacts/weights/*.bin` blobs.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from .kernels.attention import attention
from .kernels.projection import project
from .kernels.similarity import similarity

VOCAB = 4096
DIM = 256
HEADS = 4
HEAD_DIM = DIM // HEADS
FFN = 1024
ENC_LAYERS = 4
ENC_SEQ = 64
PREFILL_LAYERS = 2
PREFILL_SEQ = 256


# --------------------------------------------------------------------------
# Flat parameter packing
# --------------------------------------------------------------------------

@dataclass
class ParamPack:
    """Ordered (name, shape) spec for a flat f32 theta vector."""

    entries: list[tuple[str, tuple[int, ...]]] = field(default_factory=list)

    def add(self, name: str, shape: tuple[int, ...]) -> None:
        self.entries.append((name, shape))

    @property
    def total(self) -> int:
        return int(sum(np.prod(s) for _, s in self.entries))

    def slices(self, theta: jax.Array) -> dict[str, jax.Array]:
        out, off = {}, 0
        for name, shape in self.entries:
            n = int(np.prod(shape))
            out[name] = theta[off: off + n].reshape(shape)
            off += n
        return out

    def init(self, seed: int) -> np.ndarray:
        """Deterministic weights: per-entry scaled gaussian, single PRNG."""
        rng = np.random.RandomState(seed)
        parts = []
        for name, shape in self.entries:
            fan_in = shape[0] if len(shape) > 1 else shape[0]
            if name.endswith("_b") or ".bias" in name:
                parts.append(np.zeros(int(np.prod(shape)), dtype=np.float32))
            elif name.endswith("_g") or ".gamma" in name:
                parts.append(np.ones(int(np.prod(shape)), dtype=np.float32))
            else:
                scale = 1.0 / np.sqrt(max(fan_in, 1))
                parts.append(
                    (rng.randn(int(np.prod(shape))) * scale).astype(np.float32)
                )
        return np.concatenate(parts)


def projection_pack() -> ParamPack:
    p = ParamPack()
    p.add("w", (VOCAB, DIM))
    p.add("proj_b", (DIM,))
    return p


def transformer_pack(layers: int, *, causal: bool) -> ParamPack:
    p = ParamPack()
    p.add("tok_emb", (VOCAB, DIM))
    p.add("pos_emb", (PREFILL_SEQ if causal else ENC_SEQ, DIM))
    for i in range(layers):
        p.add(f"l{i}.wq", (DIM, DIM))
        p.add(f"l{i}.wk", (DIM, DIM))
        p.add(f"l{i}.wv", (DIM, DIM))
        p.add(f"l{i}.wo", (DIM, DIM))
        p.add(f"l{i}.ln1_g", (DIM,))
        p.add(f"l{i}.ln1_b", (DIM,))
        p.add(f"l{i}.w1", (DIM, FFN))
        p.add(f"l{i}.ffn1_b", (FFN,))
        p.add(f"l{i}.w2", (FFN, DIM))
        p.add(f"l{i}.ffn2_b", (DIM,))
        p.add(f"l{i}.ln2_g", (DIM,))
        p.add(f"l{i}.ln2_b", (DIM,))
    p.add("lnf_g", (DIM,))
    p.add("lnf_b", (DIM,))
    if causal:
        p.add("head_w", (DIM, VOCAB))
    return p


# --------------------------------------------------------------------------
# Model pieces
# --------------------------------------------------------------------------

def _layer_norm(x: jax.Array, g: jax.Array, b: jax.Array,
                eps: float = 1e-5) -> jax.Array:
    mu = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mu) ** 2, axis=-1, keepdims=True)
    return (x - mu) / jnp.sqrt(var + eps) * g + b


def _mha(x: jax.Array, mask: jax.Array, p: dict[str, jax.Array], i: int,
         *, causal: bool) -> jax.Array:
    """Multi-head attention over (b, s, DIM) through the Pallas SDPA kernel."""
    b, s, _ = x.shape
    q = x @ p[f"l{i}.wq"]
    k = x @ p[f"l{i}.wk"]
    v = x @ p[f"l{i}.wv"]

    def split(t):  # (b, s, DIM) → (b·H, s, HEAD_DIM)
        return (t.reshape(b, s, HEADS, HEAD_DIM)
                 .transpose(0, 2, 1, 3)
                 .reshape(b * HEADS, s, HEAD_DIM))

    kmask = jnp.repeat(mask, HEADS, axis=0)  # (b·H, s)
    o = attention(split(q), split(k), split(v), kmask, causal=causal)
    o = (o.reshape(b, HEADS, s, HEAD_DIM)
          .transpose(0, 2, 1, 3)
          .reshape(b, s, DIM))
    return o @ p[f"l{i}.wo"]


def _block(x: jax.Array, mask: jax.Array, p: dict[str, jax.Array], i: int,
           *, causal: bool) -> jax.Array:
    h = x + _mha(_layer_norm(x, p[f"l{i}.ln1_g"], p[f"l{i}.ln1_b"]),
                 mask, p, i, causal=causal)
    z = _layer_norm(h, p[f"l{i}.ln2_g"], p[f"l{i}.ln2_b"])
    z = jax.nn.gelu(z @ p[f"l{i}.w1"] + p[f"l{i}.ffn1_b"])
    return h + z @ p[f"l{i}.w2"] + p[f"l{i}.ffn2_b"]


def _transformer(theta: jax.Array, ids: jax.Array, mask: jax.Array, *,
                 layers: int, causal: bool) -> tuple[jax.Array, dict]:
    pack = transformer_pack(layers, causal=causal)
    p = pack.slices(theta)
    s = ids.shape[1]
    x = p["tok_emb"][ids] + p["pos_emb"][None, :s, :]
    x = x * mask[:, :, None]
    for i in range(layers):
        x = _block(x, mask, p, i, causal=causal)
    x = _layer_norm(x, p["lnf_g"], p["lnf_b"])
    return x, p


# --------------------------------------------------------------------------
# Exported graphs (each becomes one or more HLO artifacts)
# --------------------------------------------------------------------------

def projection_embed(theta: jax.Array, feats: jax.Array) -> tuple[jax.Array]:
    """(b, VOCAB) counts → (b, DIM) unit embeddings via the Pallas kernel."""
    w = theta[: VOCAB * DIM].reshape(VOCAB, DIM)
    b = theta[VOCAB * DIM: VOCAB * DIM + DIM]
    return (project(feats, w, b),)


def encoder_embed(theta: jax.Array, ids: jax.Array,
                  mask: jax.Array) -> tuple[jax.Array]:
    """(b, ENC_SEQ) token ids → (b, DIM) unit embeddings (masked mean-pool)."""
    x, _ = _transformer(theta, ids, mask, layers=ENC_LAYERS, causal=False)
    denom = jnp.maximum(jnp.sum(mask, axis=1, keepdims=True), 1.0)
    pooled = jnp.sum(x * mask[:, :, None], axis=1) / denom
    norm = jnp.sqrt(jnp.sum(pooled * pooled, axis=-1, keepdims=True) + 1e-6)
    return (pooled / norm,)


def scores(q: jax.Array, e: jax.Array) -> tuple[jax.Array]:
    """(b, d) × (n, d) → (b, n) similarity scores via the Pallas kernel."""
    return (similarity(q, e),)


def prefill_logits(theta: jax.Array, ids: jax.Array) -> tuple[jax.Array]:
    """Causal prefill: (1, PREFILL_SEQ) ids → (1, VOCAB) last-position logits.

    The proxy for the LLM prefill stage of TTFT: same dataflow (embed →
    causal attention stack → head matmul), scaled down. Padding positions
    carry id 0 and are masked out.
    """
    mask = (ids != 0).astype(jnp.float32)
    mask = mask.at[:, 0].set(1.0)  # BOS always valid
    x, p = _transformer(theta, ids, mask, layers=PREFILL_LAYERS, causal=True)
    # last valid position per row
    last = jnp.maximum(jnp.sum(mask, axis=1).astype(jnp.int32) - 1, 0)
    h = x[jnp.arange(ids.shape[0]), last]  # (b, DIM)
    return (h @ p["head_w"],)
