"""Pallas kernel: hash-feature projection embedder (matmul + bias + L2-norm).

The fast embedding path: a bag-of-tokens count vector `(b, vocab)` is
projected to the embedding space and L2-normalized in one fused kernel.
This is the kernel EdgeRAG pays for on every *online embedding generation*
(the paper's core trade — compute embeddings instead of storing them), so
its cost model is what Figures 4/5 are built on.

Tiling: the contraction dimension (vocab=4096) streams through VMEM in
`(block_k, dim)` weight tiles; the output accumulator `(b, dim)` lives in
VMEM across all grid steps (index_map pins it), and the final grid step
fuses bias-add + L2 normalization so the embedding never round-trips to
HBM un-normalized.

VMEM per step (f32, b=32, block_k=512, dim=256):
  f-tile 32·512·4 = 64 KiB + w-tile 512·256·4 = 512 KiB + acc 32 KiB
  ≈ 608 KiB — 2-deep double buffering of the streamed tiles fits easily.
MXU: (b×block_k)·(block_k×dim) per step; block_k=512, dim=256 are
128-multiples so the contraction is fully MXU-tiled.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_K = 512


def project(feats: jax.Array, w: jax.Array, bias: jax.Array, *,
            block_k: int = DEFAULT_BLOCK_K, eps: float = 1e-6) -> jax.Array:
    """normalize(feats @ w + bias): (b, vocab) × (vocab, dim) → (b, dim)."""
    b, vocab = feats.shape
    vocab2, dim = w.shape
    assert vocab == vocab2
    if vocab % block_k != 0:
        block_k = vocab
    nk = vocab // block_k
    bias2 = bias.reshape(1, dim)

    def kernel(f_ref, w_ref, b_ref, o_ref):
        k = pl.program_id(0)

        @pl.when(k == 0)
        def _init():
            o_ref[...] = jnp.zeros_like(o_ref)

        o_ref[...] += jnp.dot(
            f_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
        )

        @pl.when(k == nk - 1)
        def _finish():
            x = o_ref[...] + b_ref[...]
            norm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + eps)
            o_ref[...] = x / norm

    return pl.pallas_call(
        kernel,
        grid=(nk,),
        in_specs=[
            pl.BlockSpec((b, block_k), lambda k: (0, k)),
            pl.BlockSpec((block_k, dim), lambda k: (k, 0)),
            pl.BlockSpec((1, dim), lambda k: (0, 0)),
        ],
        out_specs=pl.BlockSpec((b, dim), lambda k: (0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, dim), feats.dtype),
        interpret=True,
    )(feats, w, bias2)
