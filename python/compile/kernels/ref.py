"""Pure-jnp oracles for every Pallas kernel in this package.

These are the correctness contracts: `python/tests/` sweeps shapes and
dtypes (hypothesis) asserting `assert_allclose(kernel(...), ref(...))`.
Keep them boring and obviously-right.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp


def similarity_ref(q: jax.Array, e: jax.Array) -> jax.Array:
    """Inner-product scores between query rows and embedding rows.

    q: (b, d), e: (n, d)  →  (b, n).  With L2-normalized inputs this is
    cosine similarity — the metric EdgeRAG's IVF index uses at both levels.
    """
    return q @ e.T


def projection_ref(theta: jax.Array, feats: jax.Array, *, dim: int,
                   eps: float = 1e-6) -> jax.Array:
    """Hash-projection embedder: normalize(feats @ W + b).

    theta: flat f32[vocab*dim + dim] packing W (vocab, dim) then b (dim,).
    feats: (b, vocab) bag-of-tokens counts  →  (b, dim) unit vectors.
    """
    vocab = feats.shape[1]
    w = theta[: vocab * dim].reshape(vocab, dim)
    b = theta[vocab * dim: vocab * dim + dim]
    x = feats @ w + b[None, :]
    norm = jnp.sqrt(jnp.sum(x * x, axis=-1, keepdims=True) + eps)
    return x / norm


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array,
                  mask: jax.Array, *, causal: bool = False) -> jax.Array:
    """Scaled-dot-product attention with key padding mask.

    q, k, v: (bh, s, dh); mask: (bh, s) with 1.0 = valid key.
    Optionally causal (used by the prefill decoder proxy).
    """
    s = q.shape[1]
    scale = 1.0 / jnp.sqrt(jnp.asarray(q.shape[-1], q.dtype))
    scores = jnp.einsum("bqd,bkd->bqk", q, k) * scale
    bias = jnp.where(mask[:, None, :] > 0, 0.0, -1e9).astype(q.dtype)
    scores = scores + bias
    if causal:
        i = jnp.arange(s)[:, None]
        j = jnp.arange(s)[None, :]
        scores = scores + jnp.where(j <= i, 0.0, -1e9).astype(q.dtype)[None, :, :]
    p = jax.nn.softmax(scores, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v)
