"""Pallas kernel: tiled inner-product similarity scoring.

This is EdgeRAG's search hot spot — every centroid probe (level-1) and every
in-cluster search (level-2) is a `(b, d) × (n, d)ᵀ` scoring pass. The paper
runs it through FAISS on the Orin GPU; here it is a Pallas kernel tiled for
a TPU-style memory hierarchy:

* the query block `(b, d)` is small and stays resident in VMEM for the
  whole grid (index_map pins it to block (0, 0));
* the embedding matrix streams through VMEM in `(block_n, d)` tiles — one
  MXU-shaped (multiple-of-128 rows for f32) tile per grid step, which is
  exactly the HBM→VMEM schedule a CUDA kernel would express with
  threadblock tiling;
* each step writes an independent `(b, block_n)` slab of the output, so
  steps are trivially double-bufferable by the Mosaic pipeline.

VMEM footprint per step (f32, d=256, b≤32, block_n=128):
  q 32·256·4 = 32 KiB  +  e-tile 128·256·4 = 128 KiB  +  out 32·128·4 = 16 KiB
  ≈ 176 KiB  ≪  16 MiB VMEM — leaves room for 2-deep pipelining.
MXU: the inner op is a (b×d)·(d×block_n) matmul; with d=256, block_n=128
both contraction and lane dims are 128-multiples, so the systolic array is
fully tiled (utilization bound by b: b≥8 keeps ≥6% of peak per step, and
the grid keeps the pipeline busy; see DESIGN.md §8).

interpret=True everywhere: the CPU PJRT plugin cannot execute Mosaic
custom-calls; interpret mode lowers to plain HLO, preserving numerics.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 128


def similarity(q: jax.Array, e: jax.Array, *,
               block_n: int = DEFAULT_BLOCK_N) -> jax.Array:
    """Scores (b, n) = q (b, d) @ e (n, d)ᵀ, tiled over n.

    `n` must be a multiple of `block_n` (the embedding service pads cluster
    matrices to shape buckets, so this holds by construction on the serving
    path).
    """
    b, d = q.shape
    n, d2 = e.shape
    assert d == d2, f"dim mismatch {d} vs {d2}"
    if n % block_n != 0:
        # Shrink the tile for small/odd inputs (tests); serving shapes are
        # pre-padded to 128-multiples.
        block_n = n
    grid = (n // block_n,)

    def kernel(q_ref, e_ref, o_ref):
        # (b, d) @ (d, block_n) → one output slab per grid step.
        o_ref[...] = jnp.dot(
            q_ref[...], e_ref[...].T, preferred_element_type=o_ref.dtype
        )

    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((b, d), lambda i: (0, 0)),
            pl.BlockSpec((block_n, d), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((b, block_n), lambda i: (0, i)),
        out_shape=jax.ShapeDtypeStruct((b, n), q.dtype),
        interpret=True,
    )(q, e)
