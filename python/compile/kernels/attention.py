"""Pallas kernel: fused scaled-dot-product attention.

Used by both L2 models that contain transformers: the gte-style embedding
encoder (padding mask) and the LLM prefill proxy (causal mask). One grid
step processes one (batch·head) slice entirely in VMEM — at the serving
sequence lengths (s=64 encoder, s=256 prefill) the whole s×s score matrix
fits comfortably, so a flash-style online softmax would only add overhead:

  s=256, dh=64, f32: q/k/v 3·256·64·4 = 192 KiB, scores 256·256·4 = 256 KiB
  → ≈ 0.5 MiB per step, ≪ VMEM. (A flash variant becomes worthwhile past
  s≈2k; DESIGN.md §8 records the crossover estimate.)

The mask is passed as a (bh, s) validity vector rather than materialized
(bh, s, s) bias — the kernel broadcasts it in-register, which is the main
fusion win over the naive L2 composition.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def attention(q: jax.Array, k: jax.Array, v: jax.Array, mask: jax.Array, *,
              causal: bool = False) -> jax.Array:
    """SDPA over (bh, s, dh) with key-padding mask (bh, s); 1.0 = valid."""
    bh, s, dh = q.shape
    scale = 1.0 / float(dh) ** 0.5

    def kernel(q_ref, k_ref, v_ref, m_ref, o_ref):
        qq = q_ref[0]          # (s, dh)
        kk = k_ref[0]
        vv = v_ref[0]
        scores = jnp.dot(qq, kk.T, preferred_element_type=qq.dtype) * scale
        valid = m_ref[0][None, :] > 0            # (1, s) key mask
        scores = jnp.where(valid, scores, -1e9)
        if causal:
            i = jax.lax.broadcasted_iota(jnp.int32, (s, s), 0)
            j = jax.lax.broadcasted_iota(jnp.int32, (s, s), 1)
            scores = jnp.where(j <= i, scores, -1e9)
        # numerically-stable softmax, fused in-kernel
        m = jnp.max(scores, axis=-1, keepdims=True)
        p = jnp.exp(scores - m)
        p = p / jnp.sum(p, axis=-1, keepdims=True)
        o_ref[0] = jnp.dot(p, vv, preferred_element_type=qq.dtype)

    return pl.pallas_call(
        kernel,
        grid=(bh,),
        in_specs=[
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
            pl.BlockSpec((1, s), lambda i: (i, 0)),
        ],
        out_specs=pl.BlockSpec((1, s, dh), lambda i: (i, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s, dh), q.dtype),
        interpret=True,
    )(q, k, v, mask)


attention_causal = functools.partial(attention, causal=True)
