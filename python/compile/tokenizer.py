"""Deterministic hashed tokenizer — the python mirror of
`rust/src/embedding/tokenizer.rs`.

Both sides must agree bit-for-bit: the rust coordinator tokenizes on the
request path, while python uses the same scheme at build/test time to
validate kernels and to produce golden vectors.

Scheme
------
* lowercase, split on any non-alphanumeric byte
* token id = 2 + (FNV-1a-32(word) % (VOCAB - 2))   (0 = PAD, 1 = CLS)
* bag-of-tokens features: raw counts per id (exact in f32), used by the
  hash-projection embedder
* sequence form: [CLS] + ids, truncated/zero-padded to a fixed length,
  used by the transformer embedder
"""

from __future__ import annotations

import numpy as np

VOCAB = 4096
PAD_ID = 0
CLS_ID = 1
SEQ_LEN = 64

_FNV_OFFSET = 0x811C9DC5
_FNV_PRIME = 0x01000193
_MASK = 0xFFFFFFFF


def fnv1a32(data: bytes) -> int:
    h = _FNV_OFFSET
    for byte in data:
        h ^= byte
        h = (h * _FNV_PRIME) & _MASK
    return h


def words(text: str) -> list[str]:
    out, cur = [], []
    for ch in text.lower():
        if ch.isascii() and (ch.isalnum()):
            cur.append(ch)
        else:
            if cur:
                out.append("".join(cur))
                cur = []
    if cur:
        out.append("".join(cur))
    return out


def token_id(word: str) -> int:
    return 2 + fnv1a32(word.encode("utf-8")) % (VOCAB - 2)


def token_ids(text: str) -> list[int]:
    return [token_id(w) for w in words(text)]


def features(text: str) -> np.ndarray:
    """Bag-of-tokens count vector, f32[VOCAB]."""
    f = np.zeros(VOCAB, dtype=np.float32)
    for tid in token_ids(text):
        f[tid] += 1.0
    return f


def sequence(text: str, seq_len: int = SEQ_LEN) -> tuple[np.ndarray, np.ndarray]:
    """([CLS] + ids) padded to seq_len → (ids i32[seq_len], mask f32[seq_len])."""
    ids = [CLS_ID] + token_ids(text)
    ids = ids[:seq_len]
    mask = np.zeros(seq_len, dtype=np.float32)
    mask[: len(ids)] = 1.0
    arr = np.zeros(seq_len, dtype=np.int32)
    arr[: len(ids)] = ids
    return arr, mask
